"""Parameter-spec machinery (mini module system, no flax).

A model is defined once as a nested dict of ``ParamSpec`` leaves; from that
single definition we derive:

* concrete initialization (deterministic per-leaf keys via path hashing),
* abstract parameters (``ShapeDtypeStruct`` — used by the dry-run and by the
  FaaSLight Program Analyzer, neither of which may allocate),
* logical sharding axes per leaf (consumed by ``repro.sharding``),
* FaaSLight *access annotations*: whether a leaf is densely consumed by an
  entry or sparsely/conditionally consumed (the seed information for tier
  splitting — the model is the only layer that knows an expert table is
  routed or an embedding is row-indexed).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import flatten_with_paths, tree_from_flat

Axes = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Axes  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | lru_a | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32
    # FaaSLight access annotation:
    #   dense        — consumed in full by every invocation of its entries
    #   rows:<axis>  — row-indexed (embeddings): only touched rows are used
    #   routed       — expert-routed (leading axis = expert id)
    #   modal:<name> — only consumed by entries of modality <name>
    access: str = "dense"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_key(root: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(root, h)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    shape, dtype = spec.shape, spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "lru_a":
        # RG-LRU recurrence parameter Λ: a = sigmoid(Λ)^(c) uniform in a
        # stable band (Griffin init: a^2 ~ U[0.9, 0.999]).
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        a = jnp.sqrt(u)
        c = 8.0
        # Λ such that sigmoid(Λ) = a**(1/c)
        lam = jnp.log(a ** (1 / c)) - jnp.log1p(-(a ** (1 / c)))
        return lam.astype(dtype)
    # fan-in scaled normal. Base weights are 2D (d_in, d_out); scan stacking
    # prepends layer dims, so fan-in is always shape[-2] for ndim >= 2.
    fan_in = shape[-2] if len(shape) >= 2 else 1
    std = spec.scale / max(np.sqrt(fan_in), 1.0)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_params(spec_tree: Any, key: jax.Array, dtype_override: Any = None) -> dict:
    flat = flatten_with_paths(spec_tree)
    out = {}
    for path, spec in flat:
        leaf = _init_leaf(spec, _leaf_key(key, path))
        if dtype_override is not None and jnp.issubdtype(leaf.dtype, jnp.floating):
            leaf = leaf.astype(dtype_override)
        out[path] = leaf
    return tree_from_flat(out)


def abstract_params(spec_tree: Any, dtype_override: Any = None) -> dict:
    flat = flatten_with_paths(spec_tree)
    out = {}
    for path, spec in flat:
        dt = dtype_override if dtype_override is not None else spec.dtype
        out[path] = jax.ShapeDtypeStruct(spec.shape, dt)
    return tree_from_flat(out)


def logical_axes(spec_tree: Any) -> dict:
    return tree_from_flat({p: s.axes for p, s in flatten_with_paths(spec_tree)})


def access_annotations(spec_tree: Any) -> dict[str, str]:
    """dotted-path -> access kind, for the FaaSLight partitioner."""
    return {p: s.access for p, s in flatten_with_paths(spec_tree)}


def stack_specs(spec_tree: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    """Prepend a stacking dim of size ``n`` to every spec leaf (scan-over-
    layers parameter stacking)."""

    def _stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec(
            shape=(n,) + s.shape,
            axes=(axis_name,) + s.axes,
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
            access=s.access,
        )

    return jax.tree.map(_stack, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
