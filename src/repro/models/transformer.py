"""Decoder-stack assembly for all ten architecture families.

Layers are grouped into the minimal repeating *unit* of the config's block
pattern (1 for uniform stacks, 3 for RecurrentGemma's rec/rec/attn, 6 for
Gemma-3's 5-local:1-global, 5 for the VLM's 4-self:1-cross, 2 for xLSTM's
m/s) and the unit is scanned with stacked params — HLO size stays O(unit),
not O(depth), which keeps the 88-/100-layer dry-run compiles tractable.

Entry points (the FaaSLight "serverless functions", DESIGN.md §4.1):
  loss_fn      — training forward + xent (train_4k)
  prefill      — full forward, returns last-token logits + caches (prefill_32k)
  decode_step  — one token against caches (decode_32k / long_500k)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    chunked_xent,
    embed,
    embedding_spec,
    gelu_mlp,
    gelu_mlp_spec,
    logits_from_embedding,
    rmsnorm,
    rmsnorm_spec,
    softmax_xent,
    swiglu,
    swiglu_spec,
)
from repro.models.spec import (
    ParamSpec,
    abstract_params,
    access_annotations,
    init_params,
    logical_axes,
    stack_specs,
)
from repro.sharding import constrain

# ---------------------------------------------------------------------------
# block specs
# ---------------------------------------------------------------------------


def _mlp_spec(cfg: ModelConfig, layer_idx: int) -> dict:
    if cfg.moe is not None:
        if layer_idx < cfg.moe.first_dense_layers:
            return {"dense": swiglu_spec(cfg.d_model, cfg.moe.dense_d_ff or cfg.d_ff)}
        return {"moe": moe_mod.moe_spec(cfg)}
    return {"dense": swiglu_spec(cfg.d_model, cfg.d_ff)}


def block_spec(cfg: ModelConfig, kind: str, layer_idx: int) -> dict:
    d = cfg.d_model
    if kind in ("self", "local", "global", "attn"):
        a = attn.mla_spec(cfg) if cfg.mla is not None else attn.gqa_spec(
            d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        )
        spec = {"norm1": rmsnorm_spec(d), "attn": a, "norm2": rmsnorm_spec(d)}
        spec.update(_mlp_spec(cfg, layer_idx))
        if cfg.encdec is not None:
            spec["norm_x"] = rmsnorm_spec(d)
            spec["cross"] = attn.gqa_spec(d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim)
        return spec
    if kind == "cross":  # VLM gated image cross-attention block
        spec = {
            "norm1": rmsnorm_spec(d),
            "cross": attn.cross_attn_spec(
                d, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.vlm.vision_dim
            ),
            "norm2": rmsnorm_spec(d),
        }
        mlp = _mlp_spec(cfg, layer_idx)
        # the whole block only runs for multimodal requests; both halves are
        # zero-init gated (Llama-3.2-vision: gate_attn AND gate_ffn)
        spec.update(jax.tree.map(
            lambda s: ParamSpec(s.shape, s.axes, s.init, s.scale, s.dtype, "modal:image"),
            mlp, is_leaf=lambda x: isinstance(x, ParamSpec)))
        spec["gate_ffn"] = ParamSpec((1,), (None,), init="zeros", access="modal:image")
        return spec
    if kind == "rec":
        return {
            "norm1": rmsnorm_spec(d),
            "rglru": rec_mod.rglru_block_spec(cfg),
            "norm2": rmsnorm_spec(d),
            **_mlp_spec(cfg, layer_idx),
        }
    if kind == "m":
        return {"norm": rmsnorm_spec(d), "mlstm": xlstm_mod.mlstm_block_spec(cfg)}
    if kind == "s":
        return {"norm": rmsnorm_spec(d), "slstm": xlstm_mod.slstm_block_spec(cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# stack layout: lead (unscanned) + scanned groups + tail (unscanned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StackLayout:
    lead_kinds: tuple
    unit_kinds: tuple  # kinds inside one scanned group
    n_groups: int
    tail_kinds: tuple

    @property
    def num_layers(self) -> int:
        return len(self.lead_kinds) + self.n_groups * len(self.unit_kinds) + len(self.tail_kinds)


def stack_layout(cfg: ModelConfig) -> StackLayout:
    kinds = list(cfg.attn_kinds)
    lead = cfg.moe.first_dense_layers if cfg.moe else 0
    lead_kinds = tuple(kinds[:lead])
    rest = kinds[lead:]
    if cfg.recurrent is not None:
        unit = len(cfg.recurrent.pattern)
    elif cfg.xlstm is not None:
        unit = len(cfg.xlstm.pattern)
    elif cfg.local_global_pattern is not None:
        unit = sum(cfg.local_global_pattern)
    elif cfg.vlm is not None:
        unit = cfg.vlm.cross_attn_every
    else:
        # uniform stacks: group layers_per_unit layers per scanned unit —
        # the remat boundary count (and thus saved-activation memory)
        # drops by the same factor at unchanged recompute cost
        unit = cfg.layers_per_unit if len(rest) % max(cfg.layers_per_unit, 1) == 0 else 1
    n_groups = len(rest) // unit
    tail_kinds = tuple(rest[n_groups * unit :])
    return StackLayout(lead_kinds, tuple(rest[:unit]), n_groups, tail_kinds)


def stack_spec(cfg: ModelConfig) -> dict:
    lay = stack_layout(cfg)
    spec: dict = {"embed": embedding_spec(cfg.vocab_size, cfg.d_model)}
    if cfg.tie_embeddings:
        # tied tables are consumed densely by the logits matmul -> tier-0
        e = spec["embed"]
        spec["embed"] = ParamSpec(e.shape, e.axes, e.init, e.scale, e.dtype, access="dense")
    else:
        spec["head"] = ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))
    if lay.lead_kinds:
        spec["lead"] = {f"b{i}": block_spec(cfg, k, i) for i, k in enumerate(lay.lead_kinds)}
    if lay.n_groups:
        unit_spec = {f"u{j}": block_spec(cfg, k, len(lay.lead_kinds) + j) for j, k in enumerate(lay.unit_kinds)}
        spec["groups"] = stack_specs(unit_spec, lay.n_groups)
    if lay.tail_kinds:
        spec["tail"] = {f"b{i}": block_spec(cfg, k, cfg.num_layers - len(lay.tail_kinds) + i)
                        for i, k in enumerate(lay.tail_kinds)}
    spec["final_norm"] = rmsnorm_spec(cfg.d_model)
    if cfg.encdec is not None:
        enc_block = {
            "norm1": rmsnorm_spec(cfg.d_model),
            "attn": attn.gqa_spec(cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim),
            "norm2": rmsnorm_spec(cfg.d_model),
            **{"dense": swiglu_spec(cfg.d_model, cfg.d_ff)},
        }
        # encoder params are only reachable from entries that take raw audio
        enc_block = jax.tree.map(
            lambda s: ParamSpec(s.shape, s.axes, s.init, s.scale, s.dtype, "modal:audio"),
            enc_block, is_leaf=lambda x: isinstance(x, ParamSpec))
        spec["encoder"] = {
            "blocks": stack_specs(enc_block, cfg.encdec.num_encoder_layers),
            "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="ones", access="modal:audio"),
        }
    return spec


# ---------------------------------------------------------------------------
# per-kind forward / decode
# ---------------------------------------------------------------------------


def _kind_window(cfg: ModelConfig, kind: str) -> Optional[int]:
    if kind == "attn":  # recurrentgemma local attention
        return cfg.recurrent.window
    if kind == "local":
        return cfg.sliding_window
    if kind == "global":
        return None
    return cfg.sliding_window  # "self": SWA if the config sets it (mixtral)


def _stash_usage(cache, usage) -> None:
    """Ride the expert-usage mask on the cache pytree (serving engine's
    expert pre-fault signal; stripped by the engine before cache reuse)."""
    if cache is not None and usage is not None:
        cache["moe_usage"] = usage


def _mlp_apply(cfg: ModelConfig, params: dict, x: jax.Array, *, serving: bool = False,
               usage_rows: Optional[jax.Array] = None):
    """Returns (y, usage) — usage is the (E,) expert-touched mask when the
    config collects router stats (serving engine pre-fault), else None.
    ``serving`` selects the dropless/high-capacity MoE dispatch;
    ``usage_rows`` (B, S) bool excludes masked rows from the usage mask
    (a batched scheduler's inactive slots must not fault experts)."""
    if "moe" in params:
        if cfg.collect_moe_usage:
            return moe_mod.moe_forward(params["moe"], x, cfg, return_usage=True,
                                       serving=serving, usage_rows=usage_rows)
        return moe_mod.moe_forward(params["moe"], x, cfg, serving=serving), None
    return swiglu(params["dense"], x), None


def _block_forward(cfg, kind, params, x, positions, memory, collect_cache):
    """Returns (x, cache_or_None). memory: dict with optional 'enc'/'image'."""
    eps = cfg.norm_eps
    cache = {}
    if kind in ("self", "local", "global", "attn"):
        h = rmsnorm(x, params["norm1"], eps)
        if cfg.mla is not None:
            o, kv = attn.mla_forward(params["attn"], h, positions, cfg, return_cache=True)
            if collect_cache:
                cache["ckv"], cache["kr"] = kv
        else:
            o, (k, v) = attn.gqa_forward(
                params["attn"], h, positions, cfg,
                causal=True, window=_kind_window(cfg, kind),
                return_kv=True, use_pallas=cfg.use_pallas,
                differentiable=not collect_cache,  # prefill never backprops
            )
            if collect_cache:
                cache["k"], cache["v"] = k, v
        x = x + o
        if cfg.encdec is not None and memory.get("enc") is not None:
            hx = rmsnorm(x, params["norm_x"], eps)
            mem_kv = attn.cross_attn_memory(params["cross"], memory["enc"], cfg)
            x = x + attn.cross_attn_forward(params["cross"], hx, mem_kv, cfg)
            if collect_cache:
                cache["xk"], cache["xv"] = mem_kv
        h2 = rmsnorm(x, params["norm2"], eps)
        mlp_y, moe_usage = _mlp_apply(cfg, params, h2, serving=collect_cache)
        x = x + mlp_y
        _stash_usage(cache if collect_cache else None, moe_usage)
    elif kind == "cross":
        if memory.get("image") is not None:
            h = rmsnorm(x, params["norm1"], eps)
            mem_kv = attn.cross_attn_memory(params["cross"], memory["image"], cfg)
            x = x + attn.cross_attn_forward(params["cross"], h, mem_kv, cfg, gated=True)
            if collect_cache:
                cache["xk"], cache["xv"] = mem_kv
            h2 = rmsnorm(x, params["norm2"], eps)
            mlp_y, moe_usage = _mlp_apply(cfg, params, h2, serving=collect_cache)
            x = x + jnp.tanh(params["gate_ffn"].astype(x.dtype)) * mlp_y
            _stash_usage(cache if collect_cache else None, moe_usage)
        # text-only: the whole block is statically skipped (params unreachable)
    elif kind == "rec":
        h = rmsnorm(x, params["norm1"], eps)
        o, c = rec_mod.rglru_block_forward(params["rglru"], h, cfg, use_pallas=cfg.use_pallas)
        x = x + o
        if collect_cache:
            cache.update(c)
        h2 = rmsnorm(x, params["norm2"], eps)
        mlp_y, moe_usage = _mlp_apply(cfg, params, h2, serving=collect_cache)
        x = x + mlp_y
        _stash_usage(cache if collect_cache else None, moe_usage)
    elif kind == "m":
        h = rmsnorm(x, params["norm"], eps)
        o, c = xlstm_mod.mlstm_block_forward(params["mlstm"], h, cfg)
        x = x + o
        if collect_cache:
            cache.update(c)
    elif kind == "s":
        h = rmsnorm(x, params["norm"], eps)
        o, c = xlstm_mod.slstm_block_forward(params["slstm"], h, cfg)
        x = x + o
        if collect_cache:
            cache.update(c)
    else:
        raise ValueError(kind)
    x = constrain(x, ("batch", "seq", "embed"))
    return x, (cache if collect_cache else None)


def _block_decode(cfg, kind, params, x, pos, cache, memory, active=None):
    """x (B,1,D); returns (x, new_cache). ``active`` (B,) bool marks the
    batch rows whose routing should count toward usage masks (continuous-
    batching scheduler; None = every row counts)."""
    eps = cfg.norm_eps
    rows = active[:, None] if active is not None else None
    new_cache = dict(cache)
    if kind in ("self", "local", "global", "attn"):
        h = rmsnorm(x, params["norm1"], eps)
        if cfg.mla is not None:
            o, ckv, kr = attn.mla_decode(params["attn"], h, pos, cache["ckv"], cache["kr"], cfg)
            new_cache["ckv"], new_cache["kr"] = ckv, kr
        else:
            window = _kind_window(cfg, kind)
            rolling = window if (window is not None and cache["k"].shape[1] == window) else None
            o, kc, vc = attn.gqa_decode(
                params["attn"], h, pos, cache["k"], cache["v"], cfg, rolling_window=rolling
            )
            new_cache["k"], new_cache["v"] = kc, vc
        x = x + o
        if cfg.encdec is not None and "xk" in cache:
            hx = rmsnorm(x, params["norm_x"], eps)
            x = x + attn.cross_attn_forward(params["cross"], hx, (cache["xk"], cache["xv"]), cfg)
        h2 = rmsnorm(x, params["norm2"], eps)
        mlp_y, moe_usage = _mlp_apply(cfg, params, h2, serving=True, usage_rows=rows)
        x = x + mlp_y
        _stash_usage(new_cache, moe_usage)
    elif kind == "cross":
        if "xk" in cache:
            h = rmsnorm(x, params["norm1"], eps)
            x = x + attn.cross_attn_forward(params["cross"], h, (cache["xk"], cache["xv"]), cfg, gated=True)
            h2 = rmsnorm(x, params["norm2"], eps)
            mlp_y, moe_usage = _mlp_apply(cfg, params, h2, serving=True, usage_rows=rows)
            x = x + jnp.tanh(params["gate_ffn"].astype(x.dtype)) * mlp_y
            _stash_usage(new_cache, moe_usage)
    elif kind == "rec":
        h = rmsnorm(x, params["norm1"], eps)
        o, c = rec_mod.rglru_block_decode(params["rglru"], h, cache, cfg)
        x = x + o
        new_cache.update(c)
        h2 = rmsnorm(x, params["norm2"], eps)
        mlp_y, moe_usage = _mlp_apply(cfg, params, h2, serving=True, usage_rows=rows)
        x = x + mlp_y
        _stash_usage(new_cache, moe_usage)
    elif kind == "m":
        h = rmsnorm(x, params["norm"], eps)
        o, c = xlstm_mod.mlstm_block_decode(params["mlstm"], h, cache, cfg)
        x = x + o
        new_cache.update(c)
    elif kind == "s":
        h = rmsnorm(x, params["norm"], eps)
        o, c = xlstm_mod.slstm_block_decode(params["slstm"], h, cache, cfg)
        x = x + o
        new_cache.update(c)
    else:
        raise ValueError(kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# full-stack forward
# ---------------------------------------------------------------------------


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots_saveable":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def _encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Whisper encoder: frames (B, T, d_model) precomputed embeddings (stub
    frontend per assignment) + sinusoidal positions + non-causal self-attn."""
    B, T, D = frames.shape
    pos = jnp.arange(T)
    half = D // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(frames.dtype)
    x = frames + pe[None]
    positions = jnp.broadcast_to(pos[None], (B, T))

    def body(x, p):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        o = attn.gqa_forward(p["attn"], h, positions, cfg, causal=False, return_kv=False)
        x = x + o
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + swiglu(p["dense"], h2)
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["encoder"]["blocks"])
    return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def forward_hidden(cfg, params, tokens, *, memory=None, collect_cache=False):
    """Embed + full stack. Returns (hidden (B,S,D), caches dict or None)."""
    lay = stack_layout(cfg)
    memory = memory or {}
    B, S = tokens.shape
    x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    caches: dict[str, Any] = {}

    def apply_unscanned(section, kinds, base_idx):
        nonlocal x
        sec_caches = {}
        for i, kind in enumerate(kinds):
            x, c = _block_forward(cfg, kind, section[f"b{i}"], x, positions, memory, collect_cache)
            if collect_cache:
                sec_caches[f"b{i}"] = c
        return sec_caches

    if lay.lead_kinds:
        caches["lead"] = apply_unscanned(params["lead"], lay.lead_kinds, 0)

    if lay.n_groups:
        # nested remat for multi-layer units: the scan saves only the group
        # boundary; each block re-checkpoints so the group's backward
        # recomputes one block at a time (transients stay O(1 layer) while
        # saved boundaries shrink by layers_per_unit)
        inner_remat = cfg.remat == "inner" and len(lay.unit_kinds) > 1

        def block_step(kind, bp, x):
            return _block_forward(cfg, kind, bp, x, positions, memory, collect_cache)

        if inner_remat:
            block_step = jax.checkpoint(block_step, static_argnums=(0,))

        def group_body(x, gp):
            cs = {}
            for j, kind in enumerate(lay.unit_kinds):
                x, c = block_step(kind, gp[f"u{j}"], x)
                if collect_cache:
                    cs[f"u{j}"] = c
            return x, (cs if collect_cache else None)

        x, group_caches = jax.lax.scan(_remat(cfg, group_body), x, params["groups"])
        if collect_cache:
            caches["groups"] = group_caches

    if lay.tail_kinds:
        caches_tail = apply_unscanned(params["tail"], lay.tail_kinds, 0)
        if collect_cache:
            caches["tail"] = caches_tail

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, (caches if collect_cache else None)


# ---------------------------------------------------------------------------
# entries
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    memory = _memory_from_batch(cfg, params, batch)
    hidden, _ = forward_hidden(cfg, params, batch["tokens"], memory=memory)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    if cfg.logits_chunk:
        return chunked_xent(hidden, table, batch["labels"], cfg.logits_chunk)
    logits = logits_from_embedding(hidden, table)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return softmax_xent(logits, batch["labels"])


def prefill(cfg: ModelConfig, params: dict, batch: dict):
    """Returns (last-token logits (B, V), caches)."""
    memory = _memory_from_batch(cfg, params, batch)
    hidden, caches = forward_hidden(cfg, params, batch["tokens"], memory=memory, collect_cache=True)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = logits_from_embedding(hidden[:, -1, :], table)
    return logits, caches


def decode_step(cfg: ModelConfig, params: dict, caches: dict, batch: dict):
    """batch: tokens (B,1), pos (B,), optional active (B,) bool. Returns
    (logits (B,V), new caches). ``active`` only gates usage-mask collection
    (see ``_block_decode``); cache-row masking for inactive slots is the
    caller's job (``Model.decode_step_masked``)."""
    lay = stack_layout(cfg)
    tokens, pos = batch["tokens"], batch["pos"]
    active = batch.get("active")
    B = tokens.shape[0]
    x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    new_caches: dict[str, Any] = {}

    if lay.lead_kinds:
        sec = {}
        for i, kind in enumerate(lay.lead_kinds):
            x, c = _block_decode(cfg, kind, params["lead"][f"b{i}"], x, pos, caches["lead"][f"b{i}"], None, active=active)
            sec[f"b{i}"] = c
        new_caches["lead"] = sec

    if lay.n_groups:
        def group_body(x, xs):
            gp, gc = xs
            cs = {}
            for j, kind in enumerate(lay.unit_kinds):
                x, c = _block_decode(cfg, kind, gp[f"u{j}"], x, pos, gc[f"u{j}"], None, active=active)
                cs[f"u{j}"] = c
            return x, cs

        x, group_caches = jax.lax.scan(group_body, x, (params["groups"], caches["groups"]))
        new_caches["groups"] = group_caches

    if lay.tail_kinds:
        sec = {}
        for i, kind in enumerate(lay.tail_kinds):
            x, c = _block_decode(cfg, kind, params["tail"][f"b{i}"], x, pos, caches["tail"][f"b{i}"], None, active=active)
            sec[f"b{i}"] = c
        new_caches["tail"] = sec

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = logits_from_embedding(x[:, 0, :], table)
    return logits, new_caches


def _memory_from_batch(cfg: ModelConfig, params: dict, batch: dict) -> dict:
    memory = {}
    if cfg.encdec is not None and "frames" in batch:
        memory["enc"] = _encode(cfg, params, batch["frames"])
    if cfg.vlm is not None and "image_embeds" in batch:
        memory["image"] = batch["image_embeds"]
    return memory
