"""Griffin/RecurrentGemma RG-LRU residual block.

Temporal mixing:  y = W_out( GeLU(W_gate x) ⊙ RG-LRU(conv1d(W_in x)) )
RG-LRU:           r_t = σ(W_r h_t + b_r); i_t = σ(W_i h_t + b_i)
                  log a_t = -c · softplus(Λ) · r_t         (c = 8)
                  s_t = a_t ⊙ s_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ h_t)

Train/prefill uses an associative scan (log-space stable); decode is a
single fused step. The Pallas kernel (repro.kernels.rglru_scan) implements
the blocked time scan; this module is also its oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.spec import ParamSpec

LRU_C = 8.0


def rglru_block_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv_width
    return {
        "w_in": ParamSpec((d, w), ("embed", "ffn")),
        "w_gate_branch": ParamSpec((d, w), ("embed", "ffn")),
        "conv_w": ParamSpec((cw, w), (None, "ffn"), scale=0.5),
        "conv_b": ParamSpec((w,), ("ffn",), init="zeros"),
        "w_r": ParamSpec((w, w), ("ffn", None)),
        "b_r": ParamSpec((w,), (None,), init="zeros"),
        "w_i": ParamSpec((w, w), ("ffn", None)),
        "b_i": ParamSpec((w,), (None,), init="zeros"),
        "lam": ParamSpec((w,), (None,), init="lru_a"),
        "w_out": ParamSpec((w, d), ("ffn", "embed")),
    }


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv over time. x (B,S,W), w (cw,W).

    Returns (y, new_state) where state is the last (cw-1) inputs.
    """
    cw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype) for i in range(cw))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(cw - 1) :, :] if cw > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y, new_state


def _gates(params: dict, h: jax.Array):
    r = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", h, params["w_r"].astype(h.dtype)).astype(jnp.float32)
        + params["b_r"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", h, params["w_i"].astype(h.dtype)).astype(jnp.float32)
        + params["b_i"].astype(jnp.float32)
    )
    log_a = -LRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * h.astype(jnp.float32))
    return a, gated_x


def rglru_scan_jnp(params: dict, h: jax.Array, state: jax.Array | None = None):
    """h (B,S,W) -> (out (B,S,W), final_state (B,W)). Associative scan over
    s_t = a_t s_{t-1} + b_t."""
    a, b = _gates(params, h)  # fp32 (B,S,W)
    if state is not None:
        # fold the carried state into the first step: b_0 += a_0 * s_prev
        b = b.at[:, 0, :].add(a[:, 0, :] * state.astype(jnp.float32))

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    _, s = jax.lax.associative_scan(combine, (a, b), axis=1)
    return s.astype(h.dtype), s[:, -1, :]


def rglru_step(params: dict, h: jax.Array, state: jax.Array):
    """h (B,W) one step -> (out (B,W), new_state (B,W))."""
    a, b = _gates(params, h[:, None, :])
    s = a[:, 0] * state.astype(jnp.float32) + b[:, 0]
    return s.astype(h.dtype), s


def rglru_block_forward(params: dict, x: jax.Array, cfg: ModelConfig, *, use_pallas: bool = False):
    """Prefill/train path. Returns (y, cache) with cache = {conv, lru}."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    h = jnp.einsum("bsd,dw->bsw", x, params["w_in"].astype(x.dtype))
    h, conv_state = causal_conv1d(h, params["conv_w"], params["conv_b"])
    if use_pallas:
        from repro.kernels.rglru_scan import ops as lru_ops

        a, b = _gates(params, h)
        s = lru_ops.rglru_scan(a, b)
        s_out, lru_state = s.astype(h.dtype), s[:, -1, :]
    else:
        s_out, lru_state = rglru_scan_jnp(params, h)
    y = jnp.einsum("bsw,wd->bsd", gate * s_out, params["w_out"].astype(x.dtype))
    return y, {"conv": conv_state, "lru": lru_state.astype(x.dtype)}


def rglru_block_decode(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """x (B,1,D) one step. Returns (y (B,1,D), new_cache)."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    h = jnp.einsum("bsd,dw->bsw", x, params["w_in"].astype(x.dtype))
    h, conv_state = causal_conv1d(h, params["conv_w"], params["conv_b"], state=cache["conv"])
    s, lru_state = rglru_step(params, h[:, 0, :], cache["lru"])
    y = jnp.einsum("bsw,wd->bsd", gate * s[:, None, :], params["w_out"].astype(x.dtype))
    return y, {"conv": conv_state, "lru": lru_state.astype(x.dtype)}


def rglru_abstract_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.recurrent.lru_width or cfg.d_model
    cw = cfg.recurrent.conv_width
    return {
        "conv": jax.ShapeDtypeStruct((batch, cw - 1, w), dtype),
        "lru": jax.ShapeDtypeStruct((batch, w), dtype),
    }
