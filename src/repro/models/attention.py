"""Attention: GQA with RoPE (full / causal / sliding-window / local-global),
memory-efficient "flash-style" chunked softmax in pure jnp (also the oracle
for the Pallas kernels), decode attention over linear and rolling KV caches,
DeepSeek MLA (expanded prefill + absorbed decode), and cross-attention.

Memory discipline: no (S, S) score materialization anywhere — prefill_32k
(and long-window training) would otherwise OOM at compile time in the
dry-run. Softmax statistics are fp32 throughout.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import apply_rope
from repro.models.spec import ParamSpec

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def gqa_spec(d_model: int, num_heads: int, num_kv_heads: int, head_dim: int) -> dict:
    return {
        "wq": ParamSpec((d_model, num_heads * head_dim), ("embed", "heads")),
        "wk": ParamSpec((d_model, num_kv_heads * head_dim), ("embed", "kv_heads")),
        "wv": ParamSpec((d_model, num_kv_heads * head_dim), ("embed", "kv_heads")),
        "wo": ParamSpec((num_heads * head_dim, d_model), ("heads", "embed")),
    }


def cross_attn_spec(d_model: int, num_heads: int, num_kv_heads: int, head_dim: int, mem_dim: int) -> dict:
    spec = gqa_spec(d_model, num_heads, num_kv_heads, head_dim)
    # modal:image — only reachable from multimodal entries (FaaSLight tier-1).
    spec["wk"] = ParamSpec((mem_dim, num_kv_heads * head_dim), ("embed", "kv_heads"), access="modal:image")
    spec["wv"] = ParamSpec((mem_dim, num_kv_heads * head_dim), ("embed", "kv_heads"), access="modal:image")
    spec["wq"] = ParamSpec((d_model, num_heads * head_dim), ("embed", "heads"), access="modal:image")
    spec["wo"] = ParamSpec((num_heads * head_dim, d_model), ("heads", "embed"), access="modal:image")
    spec["gate"] = ParamSpec((1,), (None,), init="zeros", access="modal:image")
    return spec


def mla_spec(cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": ParamSpec((d, H * qd), ("embed", "heads")),
        "w_dkv": ParamSpec((d, m.kv_lora_rank), ("embed", None)),
        "w_kr": ParamSpec((d, m.qk_rope_head_dim), ("embed", None)),
        "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), init="ones"),
        "w_uk": ParamSpec((m.kv_lora_rank, H * m.qk_nope_head_dim), (None, "heads")),
        "w_uv": ParamSpec((m.kv_lora_rank, H * m.v_head_dim), (None, "heads")),
        "wo": ParamSpec((H * m.v_head_dim, d), ("heads", "embed")),
    }


# ---------------------------------------------------------------------------
# core chunked attention (pure jnp flash)
# ---------------------------------------------------------------------------


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def _softcap(s: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def flash_attention_jnp(
    q: jax.Array,  # (B, Sq, H, hd) — roped already
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,  # (B, Sk, Hkv, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # attend to the last `window` positions (incl. self)
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    softcap: Optional[float] = None,
    chunk_q: int = 512,
    chunk_k: int = 1024,
    differentiable: bool = True,
) -> jax.Array:
    """Memory-efficient attention; never materializes (Sq, Sk) scores.

    ``differentiable=False`` (serving prefill): the q-chunk loop runs as a
    lax.scan with a *dynamic* causal trip count — not reverse-differentiable,
    but transient live ranges collapse to one (bq, bk) block instead of the
    unrolled loop's O(nq) (the deepseek prefill_32k cell drops 27.8 → ~5 GiB
    peak; EXPERIMENTS.md §Perf cell 3). Training keeps the Python-unrolled
    static-trip form (exact triangle FLOPs AND grads).

    Two regimes:
      * windowed: each q-chunk attends to one dynamic k-slice of static size
        (window + chunk_q) — sub-quadratic, used for local/SWA layers;
      * general: online-softmax accumulation over k-chunks with a dynamic
        trip count per q-chunk (causal skips future blocks *exactly*, so HLO
        FLOPs match the true upper-triangle cost).
    """
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    scale = hd**-0.5
    out_dtype = q.dtype

    # auto-scale the q chunk so the unrolled general path stays ≤ ~32 bodies
    chunk_q = max(chunk_q, -(-Sq // 32))
    cq = min(chunk_q, Sq)
    # pad q to a multiple of cq
    pad_q = (-Sq) % cq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nq = q.shape[1] // cq
    qc = q.reshape(B, nq, cq, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)  # (nq,B,cq,Hkv,G,hd)

    use_window = window is not None and Sk > (window + cq)

    if use_window:
        L = window + cq

        def q_body(_, xs):
            qi, qb = xs  # qb: (B,cq,Hkv,G,hd)
            qs = qi * cq
            start = jnp.clip(qs - window + 1 + q_offset, 0, Sk - L)
            kb = jax.lax.dynamic_slice_in_dim(k, start, L, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, L, axis=1)
            q_pos = qs + q_offset + jnp.arange(cq)
            k_pos = start + jnp.arange(L)
            # operands stay in model dtype; accumulation is fp32 (MXU-native
            # mixed precision — avoids materializing fp32 q/k/v copies)
            s = jnp.einsum(
                "bqkgd,bskd->bqkgs", qb, kb, preferred_element_type=jnp.float32
            )
            s = _softcap(s * scale, softcap)
            delta = q_pos[:, None] - k_pos[None, :]  # (cq, L)
            mask = (delta >= 0) & (delta < window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum(
                "bqkgs,bskd->bqkgd", p.astype(out_dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return None, o.astype(out_dtype)

        # recompute window blocks in backward instead of saving (B,cq,·,L)
        # score/prob tensors per step — flash-attention backward semantics
        _, oc = jax.lax.scan(jax.checkpoint(q_body), None, (jnp.arange(nq), qc))
    elif not differentiable:
        ck = min(chunk_k, Sk)
        pad_k = (-Sk) % ck
        kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
        vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
        nk = kp.shape[1] // ck

        def q_body(_, xs):
            qi, qb = xs  # (B,cq,Hkv,G,hd)
            qs = qi * cq
            q_pos = qs + q_offset + jnp.arange(cq)
            m0 = jnp.full((B, cq, Hkv, G), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, cq, Hkv, G), jnp.float32)
            a0 = jnp.zeros((B, cq, Hkv, G, hd), jnp.float32)
            # static trip count (masking handles causality): ~2× the exact
            # triangle FLOPs, but the trip is visible to the loop-aware cost
            # accounting AND transients stay one block. A real Pallas kernel
            # skips masked blocks — reported via the kernelized model.
            n_need = nk

            def k_body(ki, carry):
                m, l, acc = carry
                kb = jax.lax.dynamic_slice_in_dim(kp, ki * ck, ck, axis=1)
                vb = jax.lax.dynamic_slice_in_dim(vp, ki * ck, ck, axis=1)
                k_pos = ki * ck + jnp.arange(ck)
                s = jnp.einsum(
                    "bqkgd,bskd->bqkgs", qb, kb, preferred_element_type=jnp.float32
                )
                s = _softcap(s * scale, softcap)
                mask = k_pos[None, :] < Sk
                if causal:
                    mask = mask & (k_pos[None, :] <= q_pos[:, None])
                if window is not None:
                    mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
                maskb = mask[None, :, None, None, :]
                s = jnp.where(maskb, s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None]) * maskb
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bqkgs,bskd->bqkgd", p.astype(out_dtype), vb,
                    preferred_element_type=jnp.float32,
                )
                return m_new, l_new, acc_new

            m, l, acc = jax.lax.fori_loop(0, n_need, k_body, (m0, l0, a0))
            o = acc / jnp.maximum(l, 1e-30)[..., None]
            return None, o.astype(out_dtype)

        _, oc = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))
    else:
        ck = min(chunk_k, Sk)
        pad_k = (-Sk) % ck
        kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
        vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
        nk = kp.shape[1] // ck

        # Python-unrolled q-chunk loop: per chunk the causal trip count is a
        # *static* int, so the HLO FLOPs match the exact upper-triangle cost
        # AND the whole thing is reverse-differentiable (a traced-bound
        # fori_loop is not). nq is bounded by the chunk auto-scaling above.
        o_chunks = []
        for qi in range(nq):
            qs = qi * cq
            q_pos = qs + q_offset + jnp.arange(cq)
            if causal:
                n_need = min((qs + q_offset + cq + ck - 1) // ck, nk)
            else:
                n_need = nk
            qf = qc[qi]  # model dtype; einsums accumulate fp32
            kb_all = kp[:, : n_need * ck].reshape(B, n_need, ck, Hkv, hd).transpose(1, 0, 2, 3, 4)
            vb_all = vp[:, : n_need * ck].reshape(B, n_need, ck, Hkv, hd).transpose(1, 0, 2, 3, 4)

            def k_body(carry, xs, q_pos=q_pos, qf=qf):
                m, l, acc = carry
                ki, kb, vb = xs
                k_pos = ki * ck + jnp.arange(ck)
                s = jnp.einsum(
                    "bqkgd,bskd->bqkgs", qf, kb, preferred_element_type=jnp.float32
                )
                s = _softcap(s * scale, softcap)
                mask = k_pos[None, :] < Sk  # drop k padding
                if causal:
                    mask = mask & (k_pos[None, :] <= q_pos[:, None])
                if window is not None:
                    mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
                maskb = mask[None, :, None, None, :]
                s = jnp.where(maskb, s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None]) * maskb
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bqkgs,bskd->bqkgd", p.astype(out_dtype), vb,
                    preferred_element_type=jnp.float32,
                )
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((B, cq, Hkv, G), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, cq, Hkv, G), jnp.float32)
            a0 = jnp.zeros((B, cq, Hkv, G, hd), jnp.float32)
            # checkpointed body: backward saves only the (m, l, acc)
            # carries per k-step and recomputes the score/prob blocks —
            # O(S²/ck) extra FLOPs for an O(S²·B·H) memory cut
            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(k_body), (m0, l0, a0), (jnp.arange(n_need), kb_all, vb_all)
            )
            o = acc / jnp.maximum(l, 1e-30)[..., None]
            o_chunks.append(o.astype(out_dtype))
        oc = jnp.stack(o_chunks, axis=0)

    # (nq, B, cq, Hkv, G, hd) -> (B, Sq, H, hd)
    o = oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * cq, H, hd)
    return o[:, :Sq]


def decode_attention_jnp(
    q: jax.Array,  # (B, H, hd) — roped already
    k_cache: jax.Array,  # (B, Skv, Hkv, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,  # scalar or (B,) — number of valid cache entries
    *,
    rolling: bool = False,  # rolling (mod-window) cache layout
    softcap: Optional[float] = None,
) -> jax.Array:
    """One-token attention over a KV cache. For a rolling cache every slot is
    valid once kv_len >= Skv (slot order is irrelevant to softmax)."""
    B, H, hd = q.shape
    _, Skv, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = hd**-0.5
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    s = _softcap(s * scale, softcap)
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len)
    idx = jnp.arange(Skv)
    if rolling:
        valid = idx[None, :] < jnp.minimum(kv_len, Skv)[:, None]
    else:
        valid = idx[None, :] < kv_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged KV cache (DESIGN.md §16.2): pool of fixed-size pages + page tables
# ---------------------------------------------------------------------------


def densify_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P, ps, Hkv, hd) pool + (B, NP) table -> (B, NP*ps, Hkv, hd) dense
    cache in logical order — the bridge between the paged layout and every
    dense-cache oracle."""
    B, NP = page_table.shape
    _, ps, Hkv, hd = pages.shape
    return pages[page_table].reshape(B, NP * ps, Hkv, hd)


def decode_attention_paged_jnp(
    q: jax.Array,        # (B, H, hd) — roped already
    k_pages: jax.Array,  # (P, ps, Hkv, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, NP) int32
    kv_len: jax.Array,
    *,
    rolling: bool = False,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Pure-jnp oracle for paged decode attention: densify through the
    page table, then the dense masked reference. The Pallas kernel must
    match this for ANY table permutation (pages are named, not ordered —
    tests/test_kernels.py)."""
    k_dense = densify_pages(k_pages, page_table)
    v_dense = densify_pages(v_pages, page_table)
    return decode_attention_jnp(
        q, k_dense, v_dense, kv_len, rolling=rolling, softcap=softcap
    )


def paged_kv_write(
    k_pages: jax.Array,  # (P, ps, Hkv, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, NP) int32
    slot: jax.Array,     # (B,) int32 — logical cache slot (pos, or pos % window)
    k_new: jax.Array,    # (B, Hkv, hd)
    v_new: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Write one token's K/V at logical slot ``slot[b]`` of each sequence:
    physical page = page_table[b, slot // ps], offset = slot % ps. Distinct
    sequences own disjoint pages (the PagePool contract), so the scatter
    rows never collide."""
    ps = k_pages.shape[1]
    phys = jnp.take_along_axis(page_table, (slot // ps)[:, None], axis=1)[:, 0]
    off = slot % ps
    k_pages = k_pages.at[phys, off].set(k_new)
    v_pages = v_pages.at[phys, off].set(v_new)
    return k_pages, v_pages


def paged_gqa_decode(
    params: dict,
    x: jax.Array,  # (B, 1, D)
    pos: jax.Array,  # (B,) absolute position of the new token
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    cfg: ModelConfig,
    *,
    rolling_window: Optional[int] = None,
    use_pallas: bool = False,
):
    """One decode step over a paged KV cache; returns
    (out, new_k_pages, new_v_pages). Same contract as ``gqa_decode`` with
    the (B, Skv, ...) slot cache replaced by pool + page table — greedy
    outputs are parity-tested against it (tests/test_paged_kv.py)."""
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B = x.shape[0]
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)), H)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(x.dtype)), Hkv)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(x.dtype)), Hkv)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)[:, 0]  # (B, H, hd)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)[:, 0]  # (B, Hkv, hd)
    v = v[:, 0]

    slot = (pos % rolling_window) if rolling_window else pos
    k_pages, v_pages = paged_kv_write(k_pages, v_pages, page_table, slot, k, v)
    kv_len = pos + 1
    if use_pallas:
        from repro.kernels.decode_attention import ops as da_ops

        o = da_ops.paged_decode_attention(
            q, k_pages, v_pages, page_table, kv_len,
            rolling=rolling_window is not None, softcap=cfg.attn_logit_softcap,
        )
    else:
        o = decode_attention_paged_jnp(
            q, k_pages, v_pages, page_table, kv_len,
            rolling=rolling_window is not None, softcap=cfg.attn_logit_softcap,
        )
    out = jnp.einsum("bh,hd->bd", o.reshape(B, H * hd), params["wo"].astype(x.dtype))
    return out[:, None, :], k_pages, v_pages


# ---------------------------------------------------------------------------
# GQA layer (projections + rope + attention), train/prefill and decode
# ---------------------------------------------------------------------------


def gqa_forward(
    params: dict,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    return_kv: bool = False,
    use_pallas: bool = False,
    differentiable: bool = True,
):
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)), H)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(x.dtype)), Hkv)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(x.dtype)), Hkv)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops

        o = fa_ops.flash_attention(
            q, k, v, causal=causal, window=window, softcap=cfg.attn_logit_softcap
        )
    else:
        o = flash_attention_jnp(
            q, k, v, causal=causal, window=window, softcap=cfg.attn_logit_softcap,
            differentiable=differentiable,
        )
    out = jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], H * hd), params["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(
    params: dict,
    x: jax.Array,  # (B, 1, D)
    pos: jax.Array,  # (B,) absolute position of the new token
    k_cache: jax.Array,
    v_cache: jax.Array,
    cfg: ModelConfig,
    *,
    rolling_window: Optional[int] = None,
):
    """One decode step; returns (out, new_k_cache, new_v_cache).

    Linear cache: write at index pos. Rolling cache (SWA/local layers): write
    at pos % window; softmax is order-invariant so slot order is fine.
    """
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B = x.shape[0]
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)), H)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(x.dtype)), Hkv)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(x.dtype)), Hkv)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)[:, 0]  # (B, H, hd)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)[:, 0]  # (B, Hkv, hd)
    v = v[:, 0]

    Skv = k_cache.shape[1]
    slot = (pos % rolling_window) if rolling_window else pos
    k_cache = _scatter_rows(k_cache, slot, k)
    v_cache = _scatter_rows(v_cache, slot, v)
    kv_len = pos + 1
    o = decode_attention_jnp(
        q, k_cache, v_cache, kv_len, rolling=rolling_window is not None, softcap=cfg.attn_logit_softcap
    )
    out = jnp.einsum("bh,hd->bd", o.reshape(B, H * hd), params["wo"].astype(x.dtype))
    return out[:, None, :], k_cache, v_cache


def _scatter_rows(cache: jax.Array, slot: jax.Array, row: jax.Array) -> jax.Array:
    """cache (B, S, ...), slot (B,), row (B, ...) -> cache with row written at
    [b, slot[b]] (per-sequence dynamic_update_slice — a scatter, not a full
    cache rewrite)."""

    def upd(c, s, r):
        return jax.lax.dynamic_update_slice_in_dim(c, r[None], s, axis=0)

    return jax.vmap(upd)(cache, slot, row)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): expanded prefill, absorbed decode over the latent cache
# ---------------------------------------------------------------------------


def mla_forward(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    return_cache: bool = False,
):
    """Prefill/train path (expanded heads). Cache = (latent c_kv, roped k_r)."""
    from repro.models.layers import rmsnorm

    m = cfg.mla
    H = cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    B, S, _ = x.shape
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)), H)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(x.dtype))
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_r = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(x.dtype))  # (B,S,rope)
    k_r = apply_rope(k_r[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    k_nope = _split_heads(jnp.einsum("bsr,rh->bsh", c_kv, params["w_uk"].astype(x.dtype)), H)
    value = _split_heads(jnp.einsum("bsr,rh->bsh", c_kv, params["w_uv"].astype(x.dtype)), H)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_r[:, :, None, :], (B, S, H, m.qk_rope_head_dim))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v head_dim up to qd so flash kernel sees uniform hd, then trim
    v_pad = jnp.pad(value, ((0, 0), (0, 0), (0, 0), (0, qd - m.v_head_dim)))
    # serving prefill (return_cache) doesn't differentiate: scanned q loop
    o = flash_attention_jnp(
        q_full, k_full, v_pad, causal=True, differentiable=not return_cache
    )[..., : m.v_head_dim]
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * m.v_head_dim), params["wo"].astype(x.dtype))
    if return_cache:
        return out, (c_kv, k_r)
    return out


def mla_decode(
    params: dict,
    x: jax.Array,  # (B, 1, D)
    pos: jax.Array,  # (B,)
    ckv_cache: jax.Array,  # (B, S, r)
    kr_cache: jax.Array,  # (B, S, rope_dim)
    cfg: ModelConfig,
):
    """Absorbed decode: queries projected into latent space; attention runs
    over the compressed cache directly (TPU-native MLA — no per-step K/V
    expansion; see DESIGN.md §7)."""
    from repro.models.layers import rmsnorm

    m = cfg.mla
    H = cfg.num_heads
    B = x.shape[0]
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = qd**-0.5

    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)), H)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)[:, 0]  # (B,H,rope)
    q_nope = q_nope[:, 0]  # (B,H,nope)

    c_new = jnp.einsum("bd,dr->br", x[:, 0], params["w_dkv"].astype(x.dtype))
    c_new = rmsnorm(c_new, params["kv_norm"], cfg.norm_eps)
    kr_new = jnp.einsum("bd,dr->br", x[:, 0], params["w_kr"].astype(x.dtype))
    kr_new = apply_rope(kr_new[:, None, None, :], pos[:, None], cfg.rope_theta)[:, 0, 0]

    ckv_cache = _scatter_rows(ckv_cache[:, :, None, :], pos, c_new[:, None, :])[:, :, 0, :]
    kr_cache = _scatter_rows(kr_cache[:, :, None, :], pos, kr_new[:, None, :])[:, :, 0, :]

    w_uk = params["w_uk"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)  # absorb W_uk into q
    s = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), ckv_cache.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), kr_cache.astype(jnp.float32))
    s = s * scale
    valid = jnp.arange(ckv_cache.shape[1])[None, :] < (pos + 1)[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", p, ckv_cache.astype(jnp.float32)).astype(x.dtype)
    w_uv = params["w_uv"].astype(x.dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_uv)
    out = jnp.einsum("bh,hd->bd", o.reshape(B, H * m.v_head_dim), params["wo"].astype(x.dtype))
    return out[:, None, :], ckv_cache, kr_cache


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder, VLM image layers)
# ---------------------------------------------------------------------------


def cross_attn_forward(
    params: dict,
    x: jax.Array,  # (B, S, D)
    memory_kv: tuple[jax.Array, jax.Array],  # precomputed (B, T, Hkv, hd) k/v
    cfg: ModelConfig,
    *,
    gated: bool = False,
):
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k, v = memory_kv
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)), H)
    o = flash_attention_jnp(q, k, v, causal=False)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], H * hd), params["wo"].astype(x.dtype))
    if gated:
        out = out * jnp.tanh(params["gate"].astype(x.dtype))
    return out


def cross_attn_memory(params: dict, memory: jax.Array, cfg: ModelConfig):
    """Project encoder/image memory to (k, v) once (cached across decode)."""
    Hkv = cfg.num_kv_heads
    k = _split_heads(jnp.einsum("btm,mh->bth", memory, params["wk"].astype(memory.dtype)), Hkv)
    v = _split_heads(jnp.einsum("btm,mh->bth", memory, params["wv"].astype(memory.dtype)), Hkv)
    return k, v
