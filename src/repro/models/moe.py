"""Mixture-of-Experts layer: token-choice top-k routing with capacity-based
gather/scatter dispatch (no (T, E, C) one-hot dispatch tensors — the gather
formulation keeps activation memory at k·cf·T·d and active-FLOPs-exact
compute, and shards as EP (experts over 'model') when E divides the axis,
falling back to TP-within-expert otherwise; see repro.sharding).

The routed expert tables are the canonical FaaSLight "optional functions":
``access="routed"`` marks them for the tier-1 split, and
``router_probs``/``experts_needed`` expose the router so the serving engine
can pre-fault experts before dispatch (two-phase execution; DESIGN.md §4.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import swiglu, swiglu_spec
from repro.models.spec import ParamSpec
from repro.sharding import constrain


def moe_spec(cfg: ModelConfig) -> dict:
    m: MoEConfig = cfg.moe
    d, f, E = cfg.d_model, m.expert_d_ff, m.num_experts
    spec = {
        "router": ParamSpec((d, E), ("embed", None)),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "ffn"), access="routed"),
        "w_up": ParamSpec((E, d, f), ("experts", "embed", "ffn"), access="routed"),
        "w_down": ParamSpec((E, f, d), ("experts", "ffn", "embed"), access="routed"),
    }
    if m.num_shared_experts:
        spec["shared"] = swiglu_spec(d, f * m.num_shared_experts)
    return spec


def router_probs(params: dict, x: jax.Array) -> jax.Array:
    """(..., E) softmax router probabilities (fp32)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), params["router"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def experts_needed(params: dict, x: jax.Array, top_k: int) -> jax.Array:
    """(E,) bool — which experts this batch routes to (serving pre-fault)."""
    probs = router_probs(params, x)
    E = probs.shape[-1]
    _, ids = jax.lax.top_k(probs, top_k)
    return (jax.nn.one_hot(ids, E).sum(axis=tuple(range(ids.ndim))) > 0)


def moe_forward(
    params: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    resident_mask: jax.Array | None = None,  # (E,) bool — strict-residency serving
    return_aux: bool = False,
    return_usage: bool = False,  # also return (E,) bool "expert touched" mask
    serving: bool = False,  # inference dispatch: dropless (small T) / high-capacity
    usage_rows: jax.Array | None = None,  # (B, S) bool — rows counted in usage
):
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S
    xf = x.reshape(T, d)

    probs = router_probs(params, xf)  # (T, E) fp32
    if resident_mask is not None:
        probs = jnp.where(resident_mask[None, :], probs, 0.0)
    gate_w, ids = jax.lax.top_k(probs, k)  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # capacity: active-token budget per expert. Training uses the config's
    # capacity factor (dropped tokens are a regularizer and keep the
    # dispatch shape hardware-friendly); serving must not drop tokens —
    # decode batches are small enough for exact dropless dispatch (C = T),
    # long prefills use a 2x factor (drops vanish at cf=2 in practice).
    if serving and T <= 1024:
        C = T
    elif serving:
        C = max(1, min(T, int(np.ceil(k * T * max(m.capacity_factor, 2.0) / E))))
    else:
        C = max(1, min(T, int(np.ceil(k * T * m.capacity_factor / E))))

    flat_ids = ids.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(T * k), flat_ids]  # (T*k,)
    keep = pos_in_expert < C
    slot = jnp.where(keep, flat_ids * C + pos_in_expert, E * C)  # sentinel slot

    # scatter token indices into (E*C,) dispatch table
    token_idx = jnp.arange(T * k) // k
    table = jnp.full((E * C + 1,), T, dtype=jnp.int32).at[slot].set(token_idx, mode="drop")[: E * C]

    xg = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)[table]  # (E*C, d)
    xg = xg.reshape(E, C, d)
    # NOTE dispatch-buffer sharding constraints were tried and REFUTED
    # (EXPERIMENTS.md §Perf cell 1, iterations 1.1/1.2): pinning (experts,
    # capacity) shardings forces all-to-all dispatch volumes larger than
    # the partial-sum all-reduces XLA picks unconstrained.

    g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xg, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    yg = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))  # (E, C, d)

    # combine: gather each (token, j) slot's output, weight, and sum over k
    yflat = yg.reshape(E * C, d)
    yflat = jnp.concatenate([yflat, jnp.zeros((1, d), yflat.dtype)], axis=0)
    per_slot = yflat[jnp.minimum(slot, E * C)]  # (T*k, d); dropped slots -> 0
    per_slot = jnp.where(keep[:, None], per_slot, 0)
    y = (per_slot.reshape(T, k, d) * gate_w[..., None].astype(x.dtype)).sum(axis=1)

    if m.num_shared_experts:
        y = y + swiglu(params["shared"], xf)

    y = y.reshape(B, S, d)
    usage = None
    if return_usage:
        # which experts this batch routed to (pre-capacity — a safe
        # overapproximation for the serving engine's expert pre-fault).
        # With ``usage_rows``, rows outside the mask (a scheduler's free /
        # completed slots decoding pad tokens) are scattered to the drop
        # sentinel so their routing never triggers a fault.
        usage_ids = ids
        if usage_rows is not None:
            usage_ids = jnp.where(usage_rows.reshape(T)[:, None], ids, E)
        usage = jnp.zeros((E,), bool).at[usage_ids.reshape(-1)].set(True, mode="drop")
    if return_aux:
        # switch-style load-balance loss: E * sum_e f_e * P_e
        f_e = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1), axis=0)  # fraction routed
        p_e = probs.mean(axis=0)
        aux = E * jnp.sum(f_e / k * p_e)
        return (y, aux, usage) if return_usage else (y, aux)
    return (y, usage) if return_usage else y

