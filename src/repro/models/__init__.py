from repro.models.zoo import Model, EntryPoint, build_model

__all__ = ["Model", "EntryPoint", "build_model"]
