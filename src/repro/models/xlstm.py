"""xLSTM blocks: mLSTM (matrix memory, exponentially gated — parallelizable)
and sLSTM (scalar memory with nonlinear recurrence — sequential scan).

Both use the stabilized exponential gating of the xLSTM paper
(arXiv:2405.04517): a running stabilizer m keeps exp(i), exp(f) bounded.

Shapes follow the "block" form of the paper: mLSTM blocks up-project by
``proj_factor_m`` and are self-contained (no separate FFN); sLSTM blocks run
the cell at d_model with a gated FFN tail.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.recurrent import causal_conv1d
from repro.models.spec import ParamSpec


def _groupnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head layernorm (GroupNorm with one group per head). x (..., H, hd)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_block_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    xc = cfg.xlstm
    di = int(d * xc.proj_factor_m)  # inner width
    H = cfg.num_heads
    return {
        "w_up": ParamSpec((d, 2 * di), ("embed", "ffn")),
        "conv_w": ParamSpec((xc.conv_width, di), (None, "ffn"), scale=0.5),
        "conv_b": ParamSpec((di,), ("ffn",), init="zeros"),
        "w_q": ParamSpec((di, di), ("ffn", None)),
        "w_k": ParamSpec((di, di), ("ffn", None)),
        "w_v": ParamSpec((di, di), ("ffn", None)),
        "w_if": ParamSpec((di, 2 * H), ("ffn", None), scale=0.1),
        "b_if": ParamSpec((2 * H,), (None,), init="zeros"),
        "gn_scale": ParamSpec((di,), ("ffn",), init="ones"),
        "w_down": ParamSpec((di, d), ("ffn", "embed")),
    }


def _mlstm_heads(x: jax.Array, H: int) -> jax.Array:
    b, s, di = x.shape
    return x.reshape(b, s, H, di // H)


def mlstm_scan(q, k, v, log_i, log_f, state=None):
    """Stabilized mLSTM recurrence via lax.scan over time.

    q,k,v: (B,S,H,hd) fp32; log_i/log_f: (B,S,H) fp32.
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)) or None.
    Returns (h (B,S,H,hd) fp32, final_state).
    """
    B, S, H, hd = q.shape
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs  # (B,H,hd) ... (B,H)
        m_new = jnp.maximum(lf + m, li)
        i_bar = jnp.exp(li - m_new)[..., None]
        f_bar = jnp.exp(lf + m - m_new)[..., None]
        C = f_bar[..., None] * C + i_bar[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = f_bar * n + i_bar * kt
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new))[..., None]
        h = jnp.einsum("bhdk,bhd->bhk", C, qt) / denom
        return (C, n, m_new), h

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2),
    )
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3), (C, n, m)


def mlstm_chunkwise(q, k, v, log_i, log_f, chunk: int, state=None):
    """Chunkwise-parallel stabilized mLSTM (xLSTM paper §App; GLA lineage).

    Mathematically identical to ``mlstm_scan`` (the stabilizer max
    telescopes across chunk boundaries) but processes time in blocks of
    ``chunk``: intra-chunk contributions use an (L, L) masked score matrix
    (MXU-friendly), inter-chunk contributions flow through the carried
    state. Memory for backward drops from O(S) per-step carries to
    O(S/chunk) chunk-boundary carries — the reason xlstm train_4k fits
    HBM at all (see EXPERIMENTS.md §Perf).

    q,k,v: (B,S,H,hd) fp32 (k pre-scaled by 1/sqrt(hd));
    log_i/log_f: (B,S,H) fp32. Returns ((B,S,H,hd) fp32, final_state).
    """
    B, S, H, hd = q.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    L = chunk
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    # (n, B, L, H, ...) chunked views, time-major over chunks
    qc = q.reshape(B, n, L, H, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, n, L, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, L, H, hd).transpose(1, 0, 2, 3, 4)
    lic = log_i.reshape(B, n, L, H).transpose(1, 0, 2, 3)
    lfc = log_f.reshape(B, n, L, H).transpose(1, 0, 2, 3)

    def chunk_step(carry, xs):
        C, nvec, m_prev = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        qb, kb, vb, li, lf = xs  # (B,L,H,hd) / (B,L,H)
        # cumulative log decay INCLUDING step t: B_t = sum_{s<=t} lf_s
        Bcum = jnp.cumsum(lf, axis=1)  # (B,L,H)
        # u_s = li_s - B_s (intra-chunk score offsets, rounding tolerated —
        # the h comparison absorbs it; see tolerance note below)
        u = li - Bcum
        # stabilizer: mathematically m_t = B_t + max(m_prev, max_{s<=t}(li_s
        # - B_s)), but evaluating that through the float32 cumsum drifts by
        # ~eps·|B_t| (≈1.5e-5 at S=256), off from the recurrent path's m.
        # Since m is *state* (it crosses chunk/request boundaries and is
        # compared bitwise against mlstm_scan in tests), run the exact
        # max-plus recurrence m_t = max(lf_t + m_{t-1}, li_t) instead — an
        # elementwise (B,H) scan whose ops match mlstm_scan one for one.
        def m_step(m, x_t):
            li_t, lf_t = x_t
            m_new = jnp.maximum(lf_t + m, li_t)
            return m_new, m_new

        _, m_scan = jax.lax.scan(
            m_step, m_prev, (li.transpose(1, 0, 2), lf.transpose(1, 0, 2))
        )
        m_t = m_scan.transpose(1, 0, 2)  # (B,L,H)
        # inter-chunk: exp(B_t + m_prev - m_t) * q_t C_prev   [C already
        # carries exp(-m_prev) scaling from the previous chunk]
        w_inter = jnp.exp(Bcum + m_prev[:, None, :] - m_t)  # (B,L,H)
        h_inter = jnp.einsum("blhd,bhdk->blhk", qb, C) * w_inter[..., None]
        n_inter = jnp.einsum("blhd,bhd->blh", qb, nvec) * w_inter
        # intra-chunk: D_{t,s} = exp(B_t - B_s + li_s - m_t) for s <= t
        # log D = (B_t - m_t)[t] + (li - B)[s]
        logD = (Bcum - m_t)[:, :, None, :] + u[:, None, :, :]  # (B,t,s,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(causal[None, :, :, None], jnp.exp(logD), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qb, kb) * D
        h_intra = jnp.einsum("btsh,bshd->bthd", scores, vb)
        n_intra = scores.sum(axis=2)  # (B,L,H)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_t))[..., None]
        h = (h_inter + h_intra) / denom
        # carry to next chunk (t = L row of the same stabilized recurrence)
        BL = Bcum[:, -1, :]  # (B,H)
        m_next = m_t[:, -1, :]
        w_C = jnp.exp(BL + m_prev - m_next)  # (B,H)
        w_s = jnp.exp(BL[:, None, :] - Bcum + li - m_next[:, None, :])  # (B,L,H)
        C_next = w_C[..., None, None] * C + jnp.einsum(
            "blh,blhd,blhk->bhdk", w_s, kb, vb
        )
        n_next = w_C[..., None] * nvec + jnp.einsum("blh,blhd->bhd", w_s, kb)
        return (C_next, n_next, m_next), h

    (C, nvec, m), hs = jax.lax.scan(
        chunk_step, (C0, n0, m0), (qc, kc, vc, lic, lfc)
    )
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return h, (C, nvec, m)


def _mlstm_qkv(params, x, cfg, conv_state=None):
    xc = cfg.xlstm
    H = cfg.num_heads
    up = jnp.einsum("bsd,dw->bsw", x, params["w_up"].astype(x.dtype))
    z, o_gate = jnp.split(up, 2, axis=-1)
    zc, conv_state = causal_conv1d(z, params["conv_w"], params["conv_b"], state=conv_state)
    zc = jax.nn.silu(zc.astype(jnp.float32)).astype(x.dtype)
    q = _mlstm_heads(jnp.einsum("bsw,wv->bsv", zc, params["w_q"].astype(x.dtype)), H).astype(jnp.float32)
    k = _mlstm_heads(jnp.einsum("bsw,wv->bsv", zc, params["w_k"].astype(x.dtype)), H).astype(jnp.float32)
    v = _mlstm_heads(jnp.einsum("bsw,wv->bsv", z, params["w_v"].astype(x.dtype)), H).astype(jnp.float32)
    k = k / jnp.sqrt(jnp.float32(k.shape[-1]))
    gates = jnp.einsum("bsw,wg->bsg", zc, params["w_if"].astype(x.dtype)).astype(jnp.float32) + params[
        "b_if"
    ].astype(jnp.float32)
    log_i, f_raw = jnp.split(gates, 2, axis=-1)  # (B,S,H) each
    log_f = -jax.nn.softplus(-f_raw)  # log sigmoid(f)
    return q, k, v, log_i, log_f, o_gate, conv_state


def mlstm_block_forward(params: dict, x: jax.Array, cfg: ModelConfig):
    H = cfg.num_heads
    q, k, v, log_i, log_f, o_gate, conv_state = _mlstm_qkv(params, x, cfg)
    S = x.shape[1]
    chunk = cfg.xlstm.chunk_size
    if S > chunk and S % chunk == 0:
        h, state = mlstm_chunkwise(q, k, v, log_i, log_f, chunk)
    else:
        h, state = mlstm_scan(q, k, v, log_i, log_f)
    h = h.astype(x.dtype).reshape(x.shape[0], x.shape[1], -1)
    h = _groupnorm(_mlstm_heads(h, H), params["gn_scale"].reshape(H, -1)).reshape(h.shape)
    h = h * jax.nn.silu(o_gate.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsw,wd->bsd", h, params["w_down"].astype(x.dtype))
    cache = {"C": state[0], "n": state[1], "m": state[2], "conv": conv_state}
    return y, cache


def mlstm_block_decode(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    H = cfg.num_heads
    q, k, v, log_i, log_f, o_gate, conv_state = _mlstm_qkv(params, x, cfg, conv_state=cache["conv"])
    h, state = mlstm_scan(q, k, v, log_i, log_f, state=(cache["C"], cache["n"], cache["m"]))
    h = h.astype(x.dtype).reshape(x.shape[0], 1, -1)
    h = _groupnorm(_mlstm_heads(h, H), params["gn_scale"].reshape(H, -1)).reshape(h.shape)
    h = h * jax.nn.silu(o_gate.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsw,wd->bsd", h, params["w_down"].astype(x.dtype))
    return y, {"C": state[0], "n": state[1], "m": state[2], "conv": conv_state}


def mlstm_abstract_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    xc = cfg.xlstm
    di = int(cfg.d_model * xc.proj_factor_m)
    H = cfg.num_heads
    hd = di // H
    return {
        "C": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, H, hd), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, H), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, xc.conv_width - 1, di), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_block_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    xc = cfg.xlstm
    f = int(d * xc.proj_factor_s)
    return {
        "w_zifo": ParamSpec((d, 4 * d), ("embed", "ffn")),
        "r_zifo": ParamSpec((H, hd, 4 * hd), (None, None, None), scale=0.5),
        "b_zifo": ParamSpec((4 * d,), ("ffn",), init="zeros"),
        "gn_scale": ParamSpec((d,), ("embed",), init="ones"),
        "ffn_up": ParamSpec((d, 2 * f), ("embed", "ffn")),
        "ffn_down": ParamSpec((f, d), ("ffn", "embed")),
    }


def _slstm_cell_step(params, xt, carry, H, hd):
    """xt: (B, 4*d) pre-activation from input; carry: (c, n, h, m) each (B,H,hd)
    except m (B,H,hd) too (per-channel stabilizer)."""
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hdk->bhk", h, params["r_zifo"].astype(h.dtype))  # (B,H,4*hd)
    pre = xt.reshape(xt.shape[0], H, 4 * hd).astype(jnp.float32) + rec.astype(jnp.float32)
    z, i_raw, f_raw, o_raw = jnp.split(pre, 4, axis=-1)  # (B,H,hd)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_raw)
    log_f = -jax.nn.softplus(-f_raw)  # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_bar = jnp.exp(i_raw - m_new)
    f_bar = jnp.exp(log_f + m - m_new)
    c = f_bar * c + i_bar * z
    n = f_bar * n + i_bar
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new), h_new


def slstm_cell(params, x_pre, cfg, state=None):
    """x_pre (B,S,4d). Returns (h (B,S,H,hd) fp32, state)."""
    B, S, _ = x_pre.shape
    H = cfg.num_heads
    hd = cfg.d_model // H
    if state is None:
        zeros = jnp.zeros((B, H, hd), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((B, H, hd), -1e30, jnp.float32))

    def step(carry, xt):
        return _slstm_cell_step(params, xt, carry, H, hd)

    state, hs = jax.lax.scan(step, state, x_pre.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2, 3), state


def _slstm_tail(params, h, x, cfg):
    B, S = x.shape[0], x.shape[1]
    H = cfg.num_heads
    h = _groupnorm(h.astype(x.dtype), params["gn_scale"].reshape(H, -1)).reshape(B, S, -1)
    up = jnp.einsum("bsd,df->bsf", h, params["ffn_up"].astype(x.dtype))
    a, b = jnp.split(up, 2, axis=-1)
    hf = jax.nn.gelu(a.astype(jnp.float32)).astype(x.dtype) * b
    return jnp.einsum("bsf,fd->bsd", hf, params["ffn_down"].astype(x.dtype))


def slstm_block_forward(params: dict, x: jax.Array, cfg: ModelConfig):
    x_pre = jnp.einsum("bsd,dk->bsk", x, params["w_zifo"].astype(x.dtype)) + params["b_zifo"].astype(x.dtype)
    h, state = slstm_cell(params, x_pre, cfg)
    y = _slstm_tail(params, h, x, cfg)
    return y, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}


def slstm_block_decode(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    x_pre = jnp.einsum("bsd,dk->bsk", x, params["w_zifo"].astype(x.dtype)) + params["b_zifo"].astype(x.dtype)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    h, state = slstm_cell(params, x_pre, cfg, state=state)
    y = _slstm_tail(params, h, x, cfg)
    return y, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}


def slstm_abstract_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    H = cfg.num_heads
    hd = cfg.d_model // H
    sd = jax.ShapeDtypeStruct((batch, H, hd), jnp.float32)
    return {"c": sd, "n": sd, "h": sd, "m": sd}
