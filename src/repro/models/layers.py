"""Common layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, losses.

All weights are 2D matrices (d_in, d_out); head structure is recovered by
reshape at use time (keeps ParamSpec/fan-in/sharding uniform and MXU-friendly
— the contracting dim stays a multiple of 128 for all full-size configs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 *variance accumulation* but model-dtype elementwise
    math. Never materializes an fp32 copy of x — a full upcast here makes
    XLA hoist an fp32 convert of the whole remat-saved activation stack out
    of the backward layer loop (observed +16 GiB/device on the 88-layer
    dry-run; see EXPERIMENTS.md §Perf)."""
    # square in model dtype, accumulate in fp32: x's only consumers are then
    # bf16 ops, so the convert stays on the layer-local square, not on x
    sq = x * x
    var = jnp.sum(sq, axis=-1, keepdims=True, dtype=jnp.float32) / x.shape[-1]
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu_spec(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "w_down": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))


def gelu_mlp_spec(d_model: int, d_ff: int) -> dict:
    return {
        "w_in": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "w_out": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(x.dtype))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"].astype(x.dtype))


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------


def embedding_spec(vocab: int, d_model: int) -> ParamSpec:
    # rows:0 — row-indexed access: the FaaSLight partitioner may tier vocab
    # row-groups (hot rows resident, cold rows on demand).
    return ParamSpec((vocab, d_model), ("vocab", "embed"), scale=1.0, access="rows:0")


def embed(table: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def logits_from_embedding(x: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy; logits (..., V) in any float dtype, fp32 softmax."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_xent(x: jax.Array, table: jax.Array, labels: jax.Array, chunk: int) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits: scan over
    sequence chunks, computing logits per chunk. ``chunk`` must divide S.

    This is one of the beyond-paper memory optimizations (§Perf): for
    gemma3-27b train_4k, whole-sequence logits are B·S·V·2 = 550 GB global.
    """
    B, S, D = x.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    xc = x.reshape(B, n, chunk, D).swapaxes(0, 1)  # (n, B, chunk, D)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)

    def body(acc, xs):
        xb, lb = xs
        logits = logits_from_embedding(xb, table)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)
