"""Model facade: one object per architecture binding config → params,
entries, caches, input specs, and FaaSLight metadata.

``Model.entries()`` is the Application Entry Recognition surface (DESIGN.md
§4.1): each entry is a jittable function plus abstract input specs, which is
exactly what the Program Analyzer traces and what the dry-run lowers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer as tf
from repro.models import recurrent as rec_mod
from repro.models import xlstm as xlstm_mod
from repro.models.spec import (
    abstract_params,
    access_annotations,
    init_params,
    logical_axes,
)
from repro.utils.tree import flatten_with_paths, tree_num_params

WHISPER_DECODE_ENC_LEN = 1500  # 30 s audio window for decode-mode serving


@dataclass(frozen=True)
class CacheLeaf:
    shape: tuple
    dtype: Any
    axes: tuple


@dataclass(frozen=True)
class EntryPoint:
    """(name, fn, abstract args) — the FaaSLight 'serverless function'."""

    name: str
    fn: Callable  # fn(params, *args)
    args: tuple  # abstract arg trees (ShapeDtypeStructs)
    arg_axes: tuple  # matching logical-axes trees
    kind: str  # train | prefill | decode


class Model:
    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.spec = tf.stack_spec(cfg)
        self.layout = tf.stack_layout(cfg)

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array, dtype=None) -> dict:
        return init_params(self.spec, key, dtype_override=dtype)

    def abstract(self, dtype=None) -> dict:
        return abstract_params(self.spec, dtype_override=dtype)

    def logical_axes(self) -> dict:
        return logical_axes(self.spec)

    def access(self) -> dict[str, str]:
        return access_annotations(self.spec)

    def axes(self) -> dict[str, tuple]:
        """dotted-path -> logical axes tuple (ParamSpec.axes)."""
        return {p: s.axes for p, s in flatten_with_paths(self.spec)}

    def num_params(self) -> int:
        return tree_num_params(self.abstract())

    def active_params(self) -> int:
        """Parameters touched per token (MoE experts scaled by top_k/E)."""
        total = 0
        access = self.access()
        m = self.cfg.moe
        for path, leaf in flatten_with_paths(self.abstract()):
            n = int(np.prod(leaf.shape))
            if access.get(path) == "routed" and m is not None:
                n = int(n * m.top_k / m.num_experts)
            total += n
        return total

    # -- forward fns ---------------------------------------------------------
    def loss_fn(self, params, batch):
        return tf.loss_fn(self.cfg, params, batch)

    def prefill(self, params, batch):
        return tf.prefill(self.cfg, params, batch)

    def decode_step(self, params, caches, batch):
        return tf.decode_step(self.cfg, params, caches, batch)

    def decode_step_masked(self, params, caches, batch):
        """One decode step over a scheduler's slot batch — requires
        ``batch["active"]`` (the continuous-batching entry, DESIGN.md §9).

        ``active`` gates exactly one thing: usage-mask collection
        (``moe_forward(usage_rows=...)``), so a free/completed slot
        decoding a pad token can never fault a cold expert in. Inactive
        rows otherwise compute garbage that is never read — their logits
        are ignored and their cache rows are rebuilt from zeros at the
        next admission (``scheduler._graft_slot_cache``), so there is no
        per-leaf select on the request path (an earlier variant froze
        inactive rows with a full-cache ``where`` merge; that copy cost
        more per step than the batching saved)."""
        if "active" not in batch:
            raise ValueError("decode_step_masked needs batch['active'] (B,) bool")
        return tf.decode_step(self.cfg, params, caches, batch)

    # -- caches --------------------------------------------------------------
    def _block_cache_template(self, kind: str, B: int, S_max: int, multimodal: bool) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        out: dict[str, CacheLeaf] = {}
        if kind in ("self", "local", "global", "attn"):
            if cfg.mla is not None:
                m = cfg.mla
                out["ckv"] = CacheLeaf((B, S_max, m.kv_lora_rank), dt, ("batch", "kv_seq", None))
                out["kr"] = CacheLeaf((B, S_max, m.qk_rope_head_dim), dt, ("batch", "kv_seq", None))
            else:
                window = tf._kind_window(cfg, kind)
                Skv = min(S_max, window) if window else S_max
                out["k"] = CacheLeaf((B, Skv, Hkv, hd), dt, ("batch", "kv_seq", "kv_heads", None))
                out["v"] = CacheLeaf((B, Skv, Hkv, hd), dt, ("batch", "kv_seq", "kv_heads", None))
            if cfg.encdec is not None and multimodal:
                # audio-serving caches only; text-only decode must match a
                # text-only prefill (no cross-attn state at all)
                T = WHISPER_DECODE_ENC_LEN
                out["xk"] = CacheLeaf((B, T, Hkv, hd), dt, ("batch", None, "kv_heads", None))
                out["xv"] = CacheLeaf((B, T, Hkv, hd), dt, ("batch", None, "kv_heads", None))
        elif kind == "cross":
            if multimodal:
                T = cfg.vlm.num_image_tokens
                out["xk"] = CacheLeaf((B, T, Hkv, hd), dt, ("batch", None, "kv_heads", None))
                out["xv"] = CacheLeaf((B, T, Hkv, hd), dt, ("batch", None, "kv_heads", None))
        elif kind == "rec":
            w = cfg.recurrent.lru_width or cfg.d_model
            cw = cfg.recurrent.conv_width
            out["conv"] = CacheLeaf((B, cw - 1, w), dt, ("batch", None, "ffn"))
            out["lru"] = CacheLeaf((B, w), dt, ("batch", "ffn"))
        elif kind == "m":
            xc = cfg.xlstm
            di = int(cfg.d_model * xc.proj_factor_m)
            H = cfg.num_heads
            hd_i = di // H
            out["C"] = CacheLeaf((B, H, hd_i, hd_i), jnp.float32, ("batch", "heads", None, None))
            out["n"] = CacheLeaf((B, H, hd_i), jnp.float32, ("batch", "heads", None))
            out["m"] = CacheLeaf((B, H), jnp.float32, ("batch", "heads"))
            out["conv"] = CacheLeaf((B, xc.conv_width - 1, di), dt, ("batch", None, "ffn"))
        elif kind == "s":
            H = cfg.num_heads
            hd_s = cfg.d_model // H
            for k in ("c", "n", "h", "m"):
                out[k] = CacheLeaf((B, H, hd_s), jnp.float32, ("batch", "heads", None))
        return out

    def cache_template(self, B: int, S_max: int, multimodal: bool = True) -> dict:
        lay = self.layout
        tpl: dict[str, Any] = {}
        if lay.lead_kinds:
            tpl["lead"] = {
                f"b{i}": self._block_cache_template(k, B, S_max, multimodal)
                for i, k in enumerate(lay.lead_kinds)
            }
        if lay.n_groups:
            unit = {
                f"u{j}": self._block_cache_template(k, B, S_max, multimodal)
                for j, k in enumerate(lay.unit_kinds)
            }

            def _stack(leaf: CacheLeaf) -> CacheLeaf:
                return CacheLeaf((lay.n_groups,) + leaf.shape, leaf.dtype, ("layers",) + leaf.axes)

            tpl["groups"] = jax.tree.map(_stack, unit, is_leaf=lambda x: isinstance(x, CacheLeaf))
        if lay.tail_kinds:
            tpl["tail"] = {
                f"b{i}": self._block_cache_template(k, B, S_max, multimodal)
                for i, k in enumerate(lay.tail_kinds)
            }
        return tpl

    def abstract_cache(self, B: int, S_max: int, multimodal: bool = True):
        tpl = self.cache_template(B, S_max, multimodal)
        return jax.tree.map(
            lambda c: jax.ShapeDtypeStruct(c.shape, c.dtype), tpl, is_leaf=lambda x: isinstance(x, CacheLeaf)
        )

    def cache_axes(self, B: int, S_max: int, multimodal: bool = True):
        tpl = self.cache_template(B, S_max, multimodal)
        return jax.tree.map(lambda c: c.axes, tpl, is_leaf=lambda x: isinstance(x, CacheLeaf))

    def init_cache(self, B: int, S_max: int, multimodal: bool = True):
        tpl = self.cache_template(B, S_max, multimodal)
        return jax.tree.map(
            lambda c: jnp.zeros(c.shape, c.dtype), tpl, is_leaf=lambda x: isinstance(x, CacheLeaf)
        )

    # -- batches -------------------------------------------------------------
    def _extra_batch_specs(self, B: int, S: int, *, multimodal: bool) -> tuple[dict, dict]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        specs, axes = {}, {}
        if cfg.encdec is not None:
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
            axes["frames"] = ("batch", "seq", "embed")
        if cfg.vlm is not None and multimodal:
            specs["image_embeds"] = jax.ShapeDtypeStruct((B, cfg.vlm.num_image_tokens, cfg.vlm.vision_dim), dt)
            axes["image_embeds"] = ("batch", None, None)
        return specs, axes

    def train_batch_spec(self, B: int, S: int, *, multimodal: bool = True) -> tuple[dict, dict]:
        i32 = jnp.int32
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        e_s, e_a = self._extra_batch_specs(B, S, multimodal=multimodal)
        specs.update(e_s)
        axes.update(e_a)
        return specs, axes

    def prefill_batch_spec(self, B: int, S: int, *, multimodal: bool = True) -> tuple[dict, dict]:
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        axes = {"tokens": ("batch", "seq")}
        e_s, e_a = self._extra_batch_specs(B, S, multimodal=multimodal)
        specs.update(e_s)
        axes.update(e_a)
        return specs, axes

    def decode_batch_spec(self, B: int) -> tuple[dict, dict]:
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
        axes = {"tokens": ("batch", None), "pos": ("batch",)}
        return specs, axes

    def decode_masked_batch_spec(self, B: int) -> tuple[dict, dict]:
        """decode_batch_spec plus the scheduler's per-slot active mask."""
        specs, axes = self.decode_batch_spec(B)
        specs["active"] = jax.ShapeDtypeStruct((B,), jnp.bool_)
        axes["active"] = ("batch",)
        return specs, axes

    # -- entry registry (Application Entry Recognition) ----------------------
    def entries(self, B: int = 1, S: int = 128, *, multimodal: Optional[bool] = None) -> list[EntryPoint]:
        """All entry points at a given (B, S). ``multimodal=None`` registers
        both modal variants for modal archs (the analyzer needs both)."""
        out = []
        modal_variants: tuple[bool, ...]
        if self.cfg.vlm is not None or self.cfg.encdec is not None:
            modal_variants = (True, False) if multimodal is None else (multimodal,)
        else:
            modal_variants = (True,)
        for mm in modal_variants:
            suffix = "" if mm else "_text_only"
            tb, ta = self.train_batch_spec(B, S, multimodal=mm)
            if not mm:
                tb.pop("frames", None)
                ta.pop("frames", None)
            out.append(EntryPoint(f"train_step{suffix}", self.loss_fn, (tb,), (ta,), "train"))
            pb, pa = self.prefill_batch_spec(B, S, multimodal=mm)
            if not mm:
                pb.pop("frames", None)
                pa.pop("frames", None)
            out.append(EntryPoint(f"prefill{suffix}", self.prefill, (pb,), (pa,), "prefill"))
            cache = self.abstract_cache(B, S, multimodal=mm)
            caxes = self.cache_axes(B, S, multimodal=mm)
            db, da = self.decode_batch_spec(B)
            out.append(EntryPoint(f"decode_step{suffix}", self.decode_step, (cache, db), (caxes, da), "decode"))
        return out

    def input_specs(self, shape: ShapeSpec, *, multimodal: bool = True) -> EntryPoint:
        """The single (arch × shape) dry-run cell entry."""
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            tb, ta = self.train_batch_spec(B, S, multimodal=multimodal)
            return EntryPoint("train_step", self.loss_fn, (tb,), (ta,), "train")
        if shape.kind == "prefill":
            pb, pa = self.prefill_batch_spec(B, S, multimodal=multimodal)
            return EntryPoint("prefill", self.prefill, (pb,), (pa,), "prefill")
        cache = self.abstract_cache(B, S, multimodal=multimodal)
        caxes = self.cache_axes(B, S, multimodal=multimodal)
        db, da = self.decode_batch_spec(B)
        return EntryPoint("decode_step", self.decode_step, (cache, db), (caxes, da), "decode")


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
