"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks (block-internal expansion, hence d_ff=0).
[arXiv:2405.04517; unverified]"""

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,  # blocks carry their own up/down projections
    vocab_size=50_304,
    head_dim=192,
    xlstm=XLSTMConfig(pattern=("m", "s"), proj_factor_m=2.0, proj_factor_s=1.333, chunk_size=128),
    tie_embeddings=True,
    source="arXiv:2405.04517",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-125m-reduced",
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        vocab_size=512,
        head_dim=32,
        xlstm=XLSTMConfig(pattern=("m", "s"), proj_factor_m=2.0, proj_factor_s=1.333, chunk_size=16),
    )
