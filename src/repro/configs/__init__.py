"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

The ten assigned architectures (see DESIGN.md §5) plus the paper-benchmark
reduced variants used by smoke tests and the cold-start benchmarks.
"""

from __future__ import annotations

from repro.configs.base import (
    SHAPES,
    EncDecConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RecurrentConfig,
    ShapeSpec,
    VLMConfig,
    XLSTMConfig,
    shape_applicable,
)

from repro.configs import (  # noqa: E402
    deepseek_v2_lite_16b,
    gemma3_27b,
    llama32_vision_90b,
    mistral_large_123b,
    mixtral_8x22b,
    phi3_medium_14b,
    recurrentgemma_9b,
    whisper_base,
    xlstm_125m,
    yi_34b,
)

_MODULES = {
    "recurrentgemma-9b": recurrentgemma_9b,
    "mistral-large-123b": mistral_large_123b,
    "gemma3-27b": gemma3_27b,
    "phi3-medium-14b": phi3_medium_14b,
    "yi-34b": yi_34b,
    "mixtral-8x22b": mixtral_8x22b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "whisper-base": whisper_base,
    "xlstm-125m": xlstm_125m,
    "llama-3.2-vision-90b": llama32_vision_90b,
}

ARCH_IDS: tuple[str, ...] = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    cfg = _MODULES[arch_id].CONFIG
    cfg.validate()
    return cfg


def get_reduced(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    cfg = _MODULES[arch_id].reduced()
    cfg.validate()
    return cfg


def grid_cells() -> list[tuple[str, str]]:
    """All applicable (arch, shape) dry-run cells (40 assigned minus the
    long_500k exclusions, which are *noted*, not silently dropped)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if ok:
                cells.append((arch, shape.name))
    return cells


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "RecurrentConfig",
    "XLSTMConfig",
    "EncDecConfig",
    "VLMConfig",
    "ShapeSpec",
    "get_config",
    "get_reduced",
    "grid_cells",
    "shape_applicable",
]
