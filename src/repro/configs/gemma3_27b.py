"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262_144,
    head_dim=128,
    rope_theta=1_000_000.0,
    sliding_window=1024,  # applies to the "local" layers
    local_global_pattern=(5, 1),
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt (scaled per assignment)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma3-27b-reduced",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        sliding_window=16,
    )
