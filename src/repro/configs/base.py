"""Architecture + shape configuration system.

Every assigned architecture is a ``ModelConfig`` (exact numbers from the
assignment) selectable via ``--arch <id>``; each also provides ``reduced()``
— a tiny same-family variant for CPU smoke tests. Input shapes are
``ShapeSpec``s; the (arch × shape) grid drives the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# family sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int  # routed experts
    top_k: int
    num_shared_experts: int = 0
    expert_d_ff: int = 0  # per-expert intermediate size
    first_dense_layers: int = 0  # leading layers that use a dense MLP
    dense_d_ff: int = 0  # intermediate size of those dense layers
    capacity_factor: float = 1.25  # einsum-dispatch capacity (train path)
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 = no query compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RecurrentConfig:
    """Griffin/RecurrentGemma: RG-LRU residual blocks mixed with local attn.

    ``pattern`` is the repeating block pattern; e.g. ("rec", "rec", "attn")
    is the paper's 2:1 recurrent:attention mix.
    """

    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: int = 0  # 0 = d_model
    conv_width: int = 4
    window: int = 2048  # local attention window


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM: alternating mLSTM (matrix memory) and sLSTM blocks."""

    pattern: Tuple[str, ...] = ("m", "s")
    proj_factor_m: float = 2.0  # mLSTM up-projection
    proj_factor_s: float = 1.333  # sLSTM ffn factor (×2 gates)
    conv_width: int = 4
    chunk_size: int = 128  # chunkwise-parallel mLSTM scan


@dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 6
    # The conv/mel frontend is a STUB per assignment: input_specs() provides
    # precomputed frame embeddings of shape (B, frames, d_model).
    frontend: str = "stub"


@dataclass(frozen=True)
class VLMConfig:
    """Llama-3.2-Vision-style: text decoder with periodic cross-attn layers
    attending to precomputed image patch embeddings (frontend = stub)."""

    cross_attn_every: int = 5  # every 5th layer is cross-attn
    num_image_tokens: int = 1601
    vision_dim: int = 7680


# ---------------------------------------------------------------------------
# the model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 = d_model // num_heads
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA for *all* attn layers
    local_global_pattern: Optional[Tuple[int, int]] = None  # (n_local, n_global)
    attn_logit_softcap: Optional[float] = None
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # engineering knobs (hillclimbable)
    scan_layers: bool = True
    layers_per_unit: int = 1  # uniform stacks: layers per scanned group
    remat: str = "full"  # none | full | dots_saveable
    use_pallas: bool = False  # pallas kernels on TPU hot paths (interpret on CPU)
    collect_moe_usage: bool = False  # serving: emit per-layer expert-usage masks
    fsdp: bool = True  # shard params over the data axis too
    logits_chunk: int = 0  # 0 = whole-sequence logits; else chunked loss
    source: str = ""  # provenance note

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_subquadratic(self) -> bool:
        """True iff *no* layer does unbounded full attention — the gate for
        the long_500k shape (see DESIGN.md §Arch-applicability)."""
        if self.family == "ssm":
            return True
        if self.recurrent is not None:
            return True  # RG-LRU + windowed local attention only
        if self.local_global_pattern is not None:
            return False  # periodic *global* layers are full attention
        if self.encdec is not None or self.vlm is not None:
            return False
        if self.mla is not None:
            return False  # MLA is full attention over the latent cache
        return self.sliding_window is not None

    @property
    def attn_kinds(self) -> Tuple[str, ...]:
        """Per-layer attention/mixer kinds, expanded over the full depth."""
        n = self.num_layers
        if self.recurrent is not None:
            pat = self.recurrent.pattern
            return tuple(pat[i % len(pat)] for i in range(n))
        if self.xlstm is not None:
            pat = self.xlstm.pattern
            return tuple(pat[i % len(pat)] for i in range(n))
        if self.local_global_pattern is not None:
            nl, ng = self.local_global_pattern
            pat = ("local",) * nl + ("global",) * ng
            return tuple(pat[i % len(pat)] for i in range(n))
        if self.vlm is not None:
            k = self.vlm.cross_attn_every
            return tuple("cross" if (i + 1) % k == 0 else "self" for i in range(n))
        return ("self",) * n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.d_model > 0 and self.num_layers > 0
        assert self.num_heads % max(self.num_kv_heads, 1) == 0 or self.mla or self.xlstm
        if self.moe:
            assert self.moe.top_k <= self.moe.num_experts


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        # tokens *processed per step*: decode steps process one new token
        # per sequence against a seq_len-deep cache.
        if self.kind == "decode":
            return self.global_batch
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(applicable, reason). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, (
            f"{cfg.name} has unbounded full-attention layers; a 512k dense KV "
            "decode is excluded by assignment rule (see DESIGN.md)"
        )
    return True, ""
