"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1_000_000.0,
    sliding_window=4096,  # SWA per assignment -> long_500k applicable
    moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=0, expert_d_ff=16384),
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mixtral-8x22b-reduced",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        sliding_window=32,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=0, expert_d_ff=128),
    )
