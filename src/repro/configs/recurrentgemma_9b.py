"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]
"""

from repro.configs.base import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    recurrent=RecurrentConfig(pattern=("rec", "rec", "attn"), lru_width=4096, conv_width=4, window=2048),
    tie_embeddings=True,
    source="arXiv:2402.19427",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="recurrentgemma-9b-reduced",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        recurrent=RecurrentConfig(pattern=("rec", "rec", "attn"), lru_width=64, conv_width=4, window=32),
    )
