"""whisper-base [audio] — 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec
transformer backbone; conv/mel frontend is a STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""

from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,  # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    head_dim=64,
    encdec=EncDecConfig(num_encoder_layers=6, frontend="stub"),
    tie_embeddings=True,
    source="arXiv:2212.04356",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-base-reduced",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        encdec=EncDecConfig(num_encoder_layers=2, frontend="stub"),
    )
