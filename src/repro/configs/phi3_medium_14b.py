"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100_352,
    head_dim=128,
    source="arXiv:2404.14219",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="phi3-medium-14b-reduced",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
    )
