"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers every 5th layer; vision frontend is a
STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    vlm=VLMConfig(cross_attn_every=5, num_image_tokens=1601, vision_dim=7680),
    source="hf:meta-llama/Llama-3.2-11B-Vision (scaled per assignment)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama-3.2-vision-90b-reduced",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        vlm=VLMConfig(cross_attn_every=5, num_image_tokens=16, vision_dim=48),
    )
