"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 (per-expert)
vocab=102400, MLA kv_lora=512, 2 shared + 64 routed experts top-6, first
layer dense. [arXiv:2405.04434; hf]

Assignment note: the assignment line reads "MoE 64e top-6 ... 2 shared+160
routed top-6"; 64 routed experts matches both the primary spec ("64e") and
the HF config of DeepSeek-V2-Lite, so we use 64 routed + 2 shared, top-6.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MLA: all heads share the latent cache
    d_ff=1408,  # per-expert intermediate
    vocab_size=102_400,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        expert_d_ff=1408,
        first_dense_layers=1,
        dense_d_ff=10944,
    ),
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-lite-16b-reduced",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=32,
        vocab_size=512,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(
            num_experts=8, top_k=2, num_shared_experts=1, expert_d_ff=32, first_dense_layers=1, dense_d_ff=128
        ),
    )
