"""Serving: cold-start manager (before/after1/after2 modes, residency
budget presets), batched generation engine with on-demand fault-in and
predictive prefetch hints, and the continuous-batching request scheduler
(DESIGN.md §9)."""

from repro.serving.cold_start import (
    RESIDENCY_PRESETS,
    ColdStartReport,
    ColdStartServer,
    cold_start,
)
from repro.serving.engine import GenerationEngine, RequestStats
from repro.serving.paged_kv import PagePool, PagePoolStats
from repro.serving.scheduler import (
    AdmissionPolicy,
    ContinuousBatchingScheduler,
    FIFOAdmission,
    Request,
    RequestQueue,
    SchedulerStats,
    SLOAdmission,
)

__all__ = [
    "RESIDENCY_PRESETS",
    "ColdStartReport",
    "ColdStartServer",
    "cold_start",
    "GenerationEngine",
    "RequestStats",
    "PagePool",
    "PagePoolStats",
    "AdmissionPolicy",
    "FIFOAdmission",
    "SLOAdmission",
    "ContinuousBatchingScheduler",
    "Request",
    "RequestQueue",
    "SchedulerStats",
]
