"""Serving: cold-start manager (before/after1/after2 modes, residency
policies) + batched generation engine with on-demand fault-in."""

from repro.serving.cold_start import ColdStartReport, ColdStartServer, cold_start
from repro.serving.engine import GenerationEngine, RequestStats

__all__ = [
    "ColdStartReport",
    "ColdStartServer",
    "cold_start",
    "GenerationEngine",
    "RequestStats",
]
