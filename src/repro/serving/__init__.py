"""Serving: cold-start manager (before/after1/after2 modes, residency
budget presets) + batched generation engine with on-demand fault-in and
predictive prefetch hints."""

from repro.serving.cold_start import (
    RESIDENCY_PRESETS,
    ColdStartReport,
    ColdStartServer,
    cold_start,
)
from repro.serving.engine import GenerationEngine, RequestStats

__all__ = [
    "RESIDENCY_PRESETS",
    "ColdStartReport",
    "ColdStartServer",
    "cold_start",
    "GenerationEngine",
    "RequestStats",
]
