"""Batched generation engine with on-demand fault-in (the request path).

The request loop implements the paper's runtime contract: execution never
fails on a cold unit — it *faults*. Two fault classes:

  * vocab rows — exact pre-fault: the ids a step will embed are known
    before the step runs, so the engine ensures their row-groups first
    (zero retries, the paper's best case);
  * routed experts — detected post-hoc from the step's router-usage masks
    (riding the cache pytree, see models.transformer._stash_usage); a miss
    faults the expert units in and re-runs the step. Because routing can
    shift once real weights replace placeholders, the retry iterates to a
    fixed point (bounded; ≤3 in practice — measured in RQ4).

With a prefetcher attached (DESIGN.md §8.2) the engine also *emits access
hints* per decoded batch so the next step's units load off the request
path: the top-k candidate tokens of the current logits hint the vocab
row-groups the next embed will touch, and each step's routed-expert set
hints the experts the next step is most likely to reuse (keeping them
LRU-fresh and re-pulling them if the budget evicted them).

Under a device-bytes budget, every step's units are pinned for the
duration of the step (``ensure(pin=True)`` … ``release()``), so eviction
can never zero a unit between its fault-in and the compute that needs it.

Decode caches round-trip through the engine, which strips the usage masks
before the next step (they are outputs, not state).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.cold_start import ColdStartServer
from repro.utils.tree import flatten_with_paths

MAX_FAULT_RETRIES = 3


@dataclass
class RequestStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    fault_s: float = 0.0
    prefill_retries: int = 0
    decode_retries: int = 0
    faulted_bytes: int = 0
    faulted_units: int = 0
    steps: int = 0
    prefetch_hits: int = 0   # demand touches served by the prefetcher
    hinted_units: int = 0    # hints this request emitted (accepted)


def _strip_usage(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {k: _strip_usage(v) for k, v in tree.items() if k != "moe_usage"}
    return tree


def _usage_masks(caches: Any) -> dict[str, np.ndarray]:
    return {
        p: np.asarray(v)
        for p, v in flatten_with_paths(caches)
        if p.endswith("moe_usage")
    }


class GenerationEngine:
    def __init__(self, server: ColdStartServer, *, max_seq: int = 256, hint_topk: int = 8):
        self.server = server
        self.model = server.model
        self.max_seq = max_seq
        self.hint_topk = hint_topk
        self.prefetcher = getattr(server, "prefetcher", None)
        self.retier_daemon = getattr(server, "retier_daemon", None)
        self._expert_units_index = self._build_expert_index()
        self._row_group = self._embed_row_group()

    def tick_retier(self, steps: int = 1) -> None:
        """Advance the online re-tiering daemon (DESIGN.md §12). Call sites
        sit BETWEEN steps — after a step's pins are released, before the
        next step's fault-in — never inside one. ``generate()`` ticks per
        decode step; the scheduler ticks at its own step() boundary (this
        method is NOT called from prefill_step/decode_once, which the
        scheduler runs inside its step)."""
        if self.retier_daemon is not None:
            self.retier_daemon.maybe_tick(steps)

    def _embed_row_group(self) -> int:
        tiered = self.server.tiered
        if tiered is None:
            return 0
        dec = tiered.plan.decisions.get("embed")
        if dec is None or dec.tier != 1 or dec.granularity != "rows":
            return 0
        return dec.units[0].rows[1] - dec.units[0].rows[0]

    # -- expert usage → unit keys --------------------------------------------
    def _build_expert_index(self) -> dict[str, list[str]]:
        """usage path ("groups.u0.moe_usage") -> expert-table param paths."""
        tiered = self.server.tiered
        if tiered is None:
            return {}
        idx: dict[str, list[str]] = {}
        for path, dec in tiered.plan.decisions.items():
            if dec.granularity != "expert" or dec.tier != 1:
                continue
            # "<prefix>.moe.w_gate" is signalled by "<prefix>.moe_usage"
            prefix = path.rsplit(".moe.", 1)[0]
            idx.setdefault(f"{prefix}.moe_usage", []).append(path)
        return idx

    def _expert_keys_from_usage(self, usage: dict[str, np.ndarray]) -> list[str]:
        """Every expert unit the step's router selected — resident ones
        included (the caller separates misses; demand-touching residents
        keeps the §11 access trace honest about what the step used)."""
        keys: list[str] = []
        for upath, mask in usage.items():
            for table in self._expert_units_index.get(upath, ()):
                if mask.ndim == 2:  # scanned: (n_groups, E)
                    for l, e in zip(*np.nonzero(mask)):
                        keys.append(f"{table}#l{l}e{e}")
                else:  # unscanned: (E,)
                    for e in np.nonzero(mask)[0]:
                        keys.append(f"{table}#e{e}")
        return keys

    # -- vocab pre-fault -------------------------------------------------------
    def row_keys_for(self, tokens: np.ndarray) -> list[str]:
        """Embed row-group unit keys the given token ids live in ([] when
        the embed table is not row-tiered). Used for the exact pre-fault
        and, by the scheduler, to tell the predictive prefetcher which
        units a step actually accessed (DESIGN.md §11.3)."""
        if not self._row_group:
            return []
        return [f"embed#rg{g}" for g in np.unique(np.asarray(tokens) // self._row_group)]

    def _prefault_rows(self, tokens: np.ndarray, stats: RequestStats, pins: list) -> list[str]:
        """Ensure (and pin) the row-groups this step will embed. Keys are
        appended to ``pins`` *before* the ensure so the caller's finally
        block releases them even if the load raises mid-batch. Returns the
        accessed keys."""
        tiered = self.server.tiered
        if tiered is None or not self._row_group:
            return []
        needed = self.row_keys_for(tokens)
        n_cold = sum(1 for k in needed if not tiered.is_resident(k))
        pins.extend(needed)
        t0 = time.perf_counter()
        moved = tiered.ensure(needed, pin=True)
        stats.fault_s += time.perf_counter() - t0
        stats.faulted_bytes += moved
        stats.faulted_units += n_cold  # incl. waits on in-flight prefetch
        return needed

    def _fault_experts(
        self, caches: Any, stats: RequestStats, pins: list
    ) -> tuple[list[str], list[str]]:
        """Ensure (and pin) every expert the last step routed to — resident
        experts included: their demand touches keep the access trace honest
        (an unprofiled preloaded expert would look demotable, DESIGN.md
        §11.1) and their pins block mid-step eviction. Returns
        ``(newly_faulted, used)``: retry is needed only while the first is
        nonempty, while hints/predictor observations want the second (a
        warm expert is still the strongest predictor of the next step);
        pins are registered before the load, as in ``_prefault_rows``."""
        tiered = self.server.tiered
        if tiered is None:
            return [], []
        used = self._expert_keys_from_usage(_usage_masks(caches))
        if not used:
            return [], []
        miss = [k for k in used if not tiered.is_resident(k)]
        pins.extend(used)
        t0 = time.perf_counter()
        moved = tiered.ensure(used, pin=True)
        stats.fault_s += time.perf_counter() - t0
        stats.faulted_bytes += moved
        stats.faulted_units += len(miss)
        return miss, used

    # -- hint emission (DESIGN.md §8.2) ----------------------------------------
    def topk_row_hints(self, logits) -> list[str]:
        """Embed row-group keys for the top-k candidate tokens of ``logits``
        ((V,), (B, V), …) — the vocab half of a predictive hint. The
        scheduler calls this per active slot and round-robin-merges the
        lists (``core.prefetch.merge_hints``) so no slot starves another."""
        if not self._row_group:
            return []
        flat = np.asarray(logits).reshape(-1, np.asarray(logits).shape[-1])
        k = min(self.hint_topk, flat.shape[-1])
        top = np.argpartition(-flat, k - 1, axis=-1)[:, :k]
        return [f"embed#rg{g}" for g in np.unique(top // self._row_group)]

    def _hint_next_step(
        self, logits, expert_keys: list[str], stats: RequestStats,
        accessed: list[str] = (),
    ) -> None:
        """Predictively warm the units the *next* step will likely touch:
        the learned successors of what this step actually accessed (via
        ``Prefetcher.observe`` when a profile-trained predictor is
        attached — DESIGN.md §11.3), then row-groups of the top-k candidate
        tokens, plus this step's routed experts (the strongest predictor of
        next-step routing)."""
        if self.prefetcher is None:
            return
        if accessed:
            stats.hinted_units += self.prefetcher.observe(accessed)
        hints: list[str] = list(expert_keys) + self.topk_row_hints(logits)
        if hints:
            stats.hinted_units += self.prefetcher.hint(hints)

    # -- step primitives (shared by generate() and the scheduler) ---------------
    def prefill_step(self, tokens: jax.Array, stats: RequestStats, *, hint: bool = True):
        """Prefill one prompt batch under the fault-in contract: exact vocab
        pre-fault, expert retry to fixed point, with the step's units pinned
        until its outputs are materialized. Returns
        ``(logits, caches, expert_keys)`` — caches usage-stripped, ready for
        grafting; ``expert_keys`` are the experts this step routed to,
        resident ones included (the scheduler merges them into its
        cross-slot hint/observe stream when ``hint`` is off)."""
        server = self.server
        tiered = server.tiered
        B, S = tokens.shape
        prefill = server.compiled_prefill(B, S)
        step_pins: list[str] = []
        expert_keys: list[str] = []
        accessed: list[str] = []
        if tiered is not None:
            tiered.set_phase("prefill")
        try:
            accessed += self._prefault_rows(np.asarray(tokens), stats, step_pins)
            fault0 = stats.fault_s
            t0 = time.perf_counter()
            batch = {"tokens": tokens}
            logits, caches = prefill(server.live_params(), batch)
            for _ in range(MAX_FAULT_RETRIES):
                newly, used = self._fault_experts(caches, stats, step_pins)
                seen = set(expert_keys)
                expert_keys.extend(k for k in used if k not in seen)
                if not newly:
                    break
                stats.prefill_retries += 1
                logits, caches = prefill(server.live_params(), batch)
            jax.block_until_ready(logits)
            stats.prefill_s += time.perf_counter() - t0 - (stats.fault_s - fault0)
        finally:
            if tiered is not None and step_pins:
                tiered.release(step_pins)
        # hint after release: evicted/still-cold predictions are loadable now
        if hint:
            self._hint_next_step(logits, expert_keys, stats,
                                 accessed=accessed + expert_keys)
        return logits, _strip_usage(caches), expert_keys

    def decode_once(
        self,
        decode_fn,
        caches: Any,
        dbatch: dict,
        stats: RequestStats,
        *,
        prefault_tokens: Optional[np.ndarray] = None,
        hint: bool = True,
    ):
        """One decode step under the fault-in contract. ``prefault_tokens``
        defaults to the batch tokens; the scheduler passes only the active
        slots' tokens so free/completed slots never fault vocab rows.
        Returns ``(logits, new_caches, expert_keys)``, caches
        usage-stripped and ready for the next step."""
        server = self.server
        tiered = server.tiered
        if prefault_tokens is None:
            prefault_tokens = np.asarray(dbatch["tokens"])
        step_pins: list[str] = []
        expert_keys: list[str] = []
        accessed: list[str] = []
        if tiered is not None:
            tiered.set_phase("decode")
        try:
            accessed += self._prefault_rows(np.asarray(prefault_tokens), stats, step_pins)
            fault0 = stats.fault_s
            t0 = time.perf_counter()
            logits, new_caches = decode_fn(server.live_params(), caches, dbatch)
            for _ in range(MAX_FAULT_RETRIES):
                newly, used = self._fault_experts(new_caches, stats, step_pins)
                seen = set(expert_keys)
                expert_keys.extend(k for k in used if k not in seen)
                if not newly:
                    break
                stats.decode_retries += 1
                logits, new_caches = decode_fn(server.live_params(), caches, dbatch)
            jax.block_until_ready(logits)
            stats.decode_s += time.perf_counter() - t0 - (stats.fault_s - fault0)
        finally:
            if tiered is not None and step_pins:
                tiered.release(step_pins)
        if hint:
            self._hint_next_step(logits, expert_keys, stats,
                                 accessed=accessed + expert_keys)
        return logits, _strip_usage(new_caches), expert_keys

    # -- request path -----------------------------------------------------------
    def generate(
        self,
        tokens: jax.Array,  # (B, S) prompt
        n_steps: int,
        *,
        greedy: bool = True,
    ) -> tuple[np.ndarray, RequestStats]:
        model, server = self.model, self.server
        tiered = server.tiered
        stats = RequestStats()
        hits_before = tiered.stats.prefetch_hits + tiered.stats.prefetch_waits if tiered else 0
        B, S = tokens.shape
        S_max = self.max_seq
        if S + n_steps > S_max:
            # a bare assert would vanish under ``python -O``; the request
            # path must reject over-length work unconditionally (the
            # scheduler turns this into an admission rejection)
            raise ValueError(
                f"request needs {S + n_steps} positions (prompt {S} + {n_steps} steps) "
                f"but the engine was compiled for max_seq={S_max}"
            )

        decode = server.compiled_decode(B)

        logits, caches, _ = self.prefill_step(tokens, stats)
        self.tick_retier()  # between steps, never inside one (§12.1)

        # move prefill caches into a max-length decode cache
        big = model.init_cache(B, S_max, multimodal=False)
        caches = _graft_prefill_cache(big, caches)

        out = [np.asarray(jnp.argmax(logits, -1), np.int32)]
        stats.steps = 1  # the prefill-produced token is step #1 (RQ4's
        # faults/step would otherwise be skewed for short generations)
        for step in range(n_steps - 1):
            tok = jnp.asarray(out[-1])[:, None]
            pos = jnp.full((B,), S + step, jnp.int32)
            dbatch = {"tokens": tok, "pos": pos}
            logits, caches, _ = self.decode_once(decode, caches, dbatch, stats)
            out.append(np.asarray(jnp.argmax(logits, -1), np.int32))
            stats.steps += 1
            self.tick_retier()
        if tiered is not None:
            stats.prefetch_hits = (
                tiered.stats.prefetch_hits + tiered.stats.prefetch_waits - hits_before
            )
        return np.stack(out, axis=1), stats


def _graft_prefill_cache(big: Any, small: Any) -> Any:
    """Write prefill-sized K/V prefixes into max-length zero caches; carry
    states (lru/mlstm/conv/latent) transfer as-is."""

    def graft(b, s):
        s = jnp.asarray(s)
        if b.shape == s.shape:
            return s
        # match leading dims; write the prefix along the (single) seq axis
        idx = tuple(slice(0, d) for d in s.shape)
        return b.at[idx].set(s)

    return jax.tree.map(graft, big, small)
