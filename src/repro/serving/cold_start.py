"""Cold-start manager: artifact → serving-ready state, with the paper's
three variants measured end to end.

Phases mirror Fig. 1 of the paper, adapted per DESIGN.md §2:

  read    — storage → host RAM (the paper's "application transmission")
  upload  — host → device + placeholder allocation ("code loading", part 1)
  compile — XLA compilation of the warm entry set ("code loading", part 2 —
            the interpreter-import analogue)

Modes:
  before — monolithic bundle: every collection read, all params uploaded
  after1 — collection-pruned bundle (① Optional File Elimination applied)
  after2 — two-tier artifact: tier-0 read+uploaded, tier-1 placeholder-
           allocated, hot units preloaded from the optional store; misses
           fault in at request time (the full FaaSLight pipeline)

Residency policies (DESIGN.md §4.2) — device-budget presets for the tier-1
residency layer (``RESIDENCY_PRESETS``):
  strict — tight budget (25% of tier-1 bytes), no prefetch: misses pay the
           full fault latency, cold units are evicted aggressively
  stats  — medium budget (50% of tier-1 bytes) + async prefetch driven by
           engine hints (the profile-guided follow-up's predictive load)
  full   — unlimited budget + prefetch (≈ *before* warm performance once
           every unit has been touched; tiered artifact layout retained)
An explicit ``device_budget_bytes`` overrides the preset's budget.

Multi-model hosting (DESIGN.md §13): pass the same ``host_arbiter=`` handle
to several ``cold_start()`` calls and the servers share ONE host-wide
device budget — each preset's budget *fraction* is reinterpreted as the
tenant's relative **share** of that budget (strict→0.25, stats→0.5,
full→1.0), and eviction becomes a global, heat-weighted decision across
every co-resident model instead of a private per-model one.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import tensorstore_lite as tsl
from repro.core.analyzer import AnalysisResult
from repro.core.arbiter import HostArbiter
from repro.core.on_demand import AccessTrace, TieredParams
from repro.core.optional_store import OptionalStore
from repro.core.prefetch import Prefetcher, TransitionPredictor
from repro.core.retier_daemon import RetierDaemon
from repro.core import snapshot as server_snapshot
from repro.models.zoo import Model
from repro.sharding.rules import param_shardings, spec_shard_divisor
from repro.utils.tree import flatten_with_paths, tree_from_flat

# residency policy -> (tier-1 budget fraction, prefetch enabled); DESIGN.md §4.2
RESIDENCY_PRESETS: dict = {
    "strict": (0.25, False),
    "stats": (0.5, True),
    "full": (None, True),
}


@dataclass
class ColdStartReport:
    mode: str
    read_s: float = 0.0
    upload_s: float = 0.0
    compile_s: float = 0.0
    bytes_read: int = 0
    bytes_uploaded: int = 0

    @property
    def total_s(self) -> float:
        return self.read_s + self.upload_s + self.compile_s

    def to_dict(self) -> dict:
        return {
            "mode": self.mode,
            "read_s": self.read_s,
            "upload_s": self.upload_s,
            "compile_s": self.compile_s,
            "total_s": self.total_s,
            "bytes_read": self.bytes_read,
            "bytes_uploaded": self.bytes_uploaded,
        }


def _block_until_ready(tree: Any) -> None:
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


class ColdStartServer:
    """A cold-started model server: live params + compiled warm entries."""

    def __init__(
        self,
        model: Model,
        params: Any,
        report: ColdStartReport,
        *,
        tiered: Optional[TieredParams] = None,
        store: Optional[OptionalStore] = None,
        prefetcher: Optional[Prefetcher] = None,
        retier_daemon: Optional[RetierDaemon] = None,
        artifact_dir: Optional[str] = None,
        admission: Any = None,
        kv_page_size: Optional[int] = None,
        kv_pages: Optional[int] = None,
    ):
        self.model = model
        self.params = params
        self.report = report
        self.tiered = tiered
        self.store = store
        self.prefetcher = prefetcher
        self.retier_daemon = retier_daemon
        self.artifact_dir = artifact_dir
        # default AdmissionPolicy for schedulers built on this server
        # (DESIGN.md §15.2); None → the scheduler's FIFO default
        self.admission = admission
        # default paged-KV pool shape for schedulers (DESIGN.md §16.2);
        # None → page size 16 and a pool exactly covering max_batch×max_seq
        self.kv_page_size = kv_page_size
        self.kv_pages = kv_pages
        self.restore_report: Optional[dict] = None  # set by restore_from=
        self._compiled: dict[tuple, Callable] = {}

    def close(self) -> None:
        """Stop the prefetch threads, flush any in-flight background
        compaction, leave the host pool (if arbitered), and release the
        store handle."""
        if self.prefetcher is not None:
            self.prefetcher.stop()
            self.prefetcher = None
        if self.retier_daemon is not None:
            # a periodic compaction may still be rewriting the artifact on
            # its worker thread (DESIGN.md §17.3) — let it finish (it reads
            # the source store through its own handle) before closing up
            self.retier_daemon.join_compaction(timeout=60.0)
        if self.tiered is not None and self.tiered.arbiter is not None:
            self.tiered.arbiter.unregister(self.tiered.tenant_name)
        if self.store is not None:
            self.store.close()
            self.store = None

    # context-manager form: the launcher/benchmarks wrap serving in
    # ``with cold_start(...) as server`` so a raising request path can
    # never leak the prefetcher's reader/uploader threads
    def __enter__(self) -> "ColdStartServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- warm-set / on-demand compilation ------------------------------------
    def compiled_prefill(self, B: int, S: int):
        key = ("prefill", B, S)
        if key not in self._compiled:
            fn = jax.jit(lambda p, b: self.model.prefill(p, b))
            self._compiled[key] = fn
        return self._compiled[key]

    def compiled_decode(self, B: int):
        key = ("decode", B)
        if key not in self._compiled:
            fn = jax.jit(lambda p, c, b: self.model.decode_step(p, c, b))
            self._compiled[key] = fn
        return self._compiled[key]

    def compiled_decode_masked(self, B: int):
        """Masked decode over ``max_batch`` slots — the continuous-batching
        scheduler's one compiled decode shape (DESIGN.md §9): inactive rows
        contribute nothing to the usage masks (so a free slot can never
        fault a unit in); their cache rows are rebuilt at next admission."""
        key = ("decode_masked", B)
        if key not in self._compiled:
            fn = jax.jit(lambda p, c, b: self.model.decode_step_masked(p, c, b))
            self._compiled[key] = fn
        return self._compiled[key]

    def live_params(self) -> Any:
        return self.tiered.tree() if self.tiered is not None else self.params

    # -- warm snapshot (DESIGN.md §15.3) --------------------------------------
    def snapshot(self) -> dict:
        """Serialize this server's warm state — residency set + LRU stamps,
        predictor table, artifact identity — as a plain-JSON dict a new
        replica can restore from (``cold_start(restore_from=...)``)."""
        if self.tiered is None:
            raise ValueError("snapshot() needs a tiered (after2) server")
        return server_snapshot.capture(
            self.tiered, prefetcher=self.prefetcher, artifact_dir=self.artifact_dir
        )


def cold_start(
    model: Model,
    artifact_dir: str,
    result: Optional[AnalysisResult] = None,
    *,
    mode: str = "after2",
    warm_shapes: tuple = ((1, 64),),  # (B, S) pairs to pre-compile
    compile_warm_set: bool = True,
    put: Optional[Callable] = None,  # leaf device_put override (sharded serving)
    residency: Optional[str] = None,  # RESIDENCY_PRESETS name (after2 only)
    device_budget_bytes: Optional[int] = None,  # overrides the preset budget
    host_arbiter: Optional[HostArbiter] = None,  # shared host budget (DESIGN.md §13)
    tenant_name: Optional[str] = None,   # arbiter registration name (default: cfg.name)
    tenant_share: Optional[float] = None,  # overrides the preset-derived share
    tenant_floor_bytes: int = 0,         # arbiter never evicts below this
    prefetch: Optional[bool] = None,  # overrides the preset prefetch default
    prefetch_batch_units: int = 8,
    trace: bool = False,  # attach an AccessTrace for profiling (DESIGN.md §11)
    predictor: Optional[TransitionPredictor] = None,  # profile-trained prefetch
    retier_online: bool = False,  # live hot-set adaptation (DESIGN.md §12)
    retier_interval: int = 32,    # daemon cadence, serving steps per tick
    retier_interval_s: Optional[float] = None,  # or wall-clock seconds
    retier_decay: float = 0.5,    # trace-window merge decay per tick
    retier_compact_every: int = 0,  # artifact rewrite every N applies (0 = never)
    fleet=None,                   # FleetController to join (DESIGN.md §14)
    replica_name: Optional[str] = None,  # fleet registration name
    mesh=None,                    # jax Mesh: shard tier-0/tier-1 puts (DESIGN.md §15.1)
    admission=None,               # default AdmissionPolicy for schedulers (§15.2)
    kv_page_size: Optional[int] = None,  # default paged-KV page size (§16.2)
    kv_pages: Optional[int] = None,      # default paged-KV pool size (§16.2)
    restore_from=None,            # snapshot dict or path: warm restore (§15.3)
) -> ColdStartServer:
    """Run one timed cold start. ``result`` is required for after2.

    ``trace=True`` attaches an ``AccessTrace`` to the tiered params so the
    serving run records per-unit demand telemetry (saved by the launcher's
    ``--profile-out``); ``predictor`` arms the prefetcher with a learned
    unit→next-unit table from a prior profiling run (``--retier-from``).
    ``retier_online=True`` attaches a ``RetierDaemon`` (which implies a
    live trace) so the hot set adapts in place without a restart — the
    engine/scheduler tick it between batches. ``fleet=`` registers the
    daemon with a ``FleetController`` (DESIGN.md §14) before the server
    is returned — i.e. before any traffic — so a late joiner against a
    controller with learned state is warm-bootstrapped synchronously.
    All are after2-only and ignored for the monolithic baselines.

    ``mesh=`` threads a jax Mesh through every device_put: tier-0 leaves
    and tier-1 placeholders land as *shards* resolved via the logical-axis
    rules (repro.sharding), and the residency budget/arbiter charge
    per-device bytes (nbytes / shard count) instead of replicated bytes
    (DESIGN.md §15.1). ``restore_from=`` (a snapshot dict or JSON path)
    re-faults a previously-warmed server's residency set and arms its
    predictor before the server is returned (DESIGN.md §15.3).
    """
    if residency is not None and residency not in RESIDENCY_PRESETS:
        raise ValueError(f"unknown residency policy {residency!r}; want one of {sorted(RESIDENCY_PRESETS)}")
    if restore_from is not None and mode != "after2":
        raise ValueError("restore_from= is after2-only (monolithic modes have no residency set)")
    report = ColdStartReport(mode=mode)
    abstract = model.abstract()

    # path-aware device placement: an explicit put= wins; else a mesh
    # resolves each leaf's logical axes to a NamedSharding (same rules as
    # training, so serving shards match checkpointed shards); else plain.
    shardings_flat = None
    if mesh is not None and put is None:
        shardings_flat = dict(
            flatten_with_paths(
                param_shardings(
                    model.logical_axes(), abstract, mesh,
                    fsdp=bool(getattr(model.cfg, "fsdp", True)),
                )
            )
        )
    if put is not None:
        user_put = put
        def _put(path, host):
            return user_put(host)
    elif shardings_flat is not None:
        def _put(path, host):
            sh = shardings_flat.get(path)
            return jax.device_put(host, sh) if sh is not None else jax.device_put(host)
    else:
        def _put(path, host):
            return jax.device_put(host)

    if mode in ("before", "after1"):
        prefix = os.path.join(artifact_dir, mode)
        t0 = time.perf_counter()
        flat = tsl.read_bundle(prefix, mmap=False)  # move all bytes
        report.bytes_read = sum(v.nbytes for v in flat.values())
        t1 = time.perf_counter()
        # upload the params collection only (other collections have no
        # device-side consumer at serving time, but their bytes were read)
        pflat = {
            p[len("params."):]: v for p, v in flat.items() if p.startswith("params.")
        }
        tree = tree_from_flat({p: _put(p, v) for p, v in pflat.items()})
        _block_until_ready(tree)
        t2 = time.perf_counter()
        report.read_s, report.upload_s = t1 - t0, t2 - t1
        report.bytes_uploaded = sum(v.nbytes for v in pflat.values())
        server = ColdStartServer(model, tree, report,
                                 artifact_dir=artifact_dir, admission=admission,
                                 kv_page_size=kv_page_size, kv_pages=kv_pages)
    elif mode == "after2":
        if result is None:
            raise ValueError("after2 cold start needs the AnalysisResult (plan)")
        plan = result.plan
        t0 = time.perf_counter()
        tier0 = tsl.read_bundle(os.path.join(artifact_dir, "tier0"), mmap=False)
        store = OptionalStore(os.path.join(artifact_dir, "optional.blob"))
        report.bytes_read = sum(v.nbytes for v in tier0.values())
        t1 = time.perf_counter()
        flat_abs = dict(flatten_with_paths(abstract))
        live_flat = {}
        for path, leaf in flat_abs.items():
            if plan.decisions[path].tier == 0:
                live_flat[path] = _put(path, tier0[path])
            else:
                # the rewritten stub: placeholder zeros, full shape/sharding
                live_flat[path] = _put(path, np.zeros(leaf.shape, leaf.dtype))
        tree = tree_from_flat(live_flat)
        _block_until_ready(tree)
        # per-leaf shard counts for residency accounting (DESIGN.md §15.1):
        # a unit of a D-way-sharded leaf costs nbytes/D per device
        shard_divisors = None
        if shardings_flat is not None:
            shard_divisors = {
                path: spec_shard_divisor(shardings_flat[path].spec, mesh)
                for path in flat_abs
            }
        # resolve the residency preset into a budget + prefetch default —
        # or, under a host arbiter, into a relative SHARE of its budget
        budget = device_budget_bytes
        want_prefetch = prefetch
        share = tenant_share
        if residency is not None:
            frac, preset_prefetch = RESIDENCY_PRESETS[residency]
            if host_arbiter is not None:
                if share is None:
                    share = frac if frac is not None else 1.0
            elif budget is None and frac is not None:
                # budget fractions apply to *charged* (per-device) tier-1
                # bytes: under a mesh each leaf counts nbytes/divisor, so
                # the same preset means the same per-device pressure
                tier1_charged = plan.tier1_bytes
                if shard_divisors:
                    tier1_charged = 0
                    for path, leaf in flat_abs.items():
                        if plan.decisions[path].tier != 0:
                            nb = int(np.prod(leaf.shape, dtype=np.int64)) * np.dtype(leaf.dtype).itemsize
                            d = shard_divisors.get(path, 1)
                            tier1_charged += nb if d <= 1 else -(-nb // d)
                budget = int(frac * tier1_charged)
                # keep the machine functional: never below two of the
                # largest units (one incoming + one pinned)
                max_unit = max((e.rsize for e in store.entries.values()), default=0)
                budget = max(budget, 2 * max_unit)
            if want_prefetch is None:
                want_prefetch = preset_prefetch
        tiered = TieredParams(tree, plan, store, device_budget_bytes=budget,
                              shard_divisors=shard_divisors)
        if host_arbiter is not None:
            # join the host pool BEFORE the hot preload so even cold-start
            # bytes are admitted by the global make-room path
            name = tenant_name or getattr(model.cfg, "name", "") or f"tenant-{id(tiered):x}"
            host_arbiter.register(
                name, tiered,
                share=share if share is not None else 1.0,
                floor_bytes=tenant_floor_bytes,
            )
        if trace or retier_online:  # the daemon needs a live trace to watch
            tiered.start_trace(AccessTrace())
        # preload the hot set (the paper's offline-profiled module-init list)
        hot = [k for d in plan.decisions.values() for k in d.resident_units]
        moved = tiered.ensure(hot, source="preload") if hot else 0
        t2 = time.perf_counter()
        report.read_s, report.upload_s = t1 - t0, t2 - t1
        report.bytes_uploaded = report.bytes_read + moved
        prefetcher = (
            Prefetcher(tiered, batch_units=prefetch_batch_units, predictor=predictor)
            if want_prefetch
            else None
        )
        daemon = None
        if fleet is not None and not retier_online:
            raise ValueError("fleet= needs retier_online=True (the fleet "
                             "federates RetierDaemons, not bare loaders)")
        if retier_online:
            daemon = RetierDaemon(
                tiered, result.reach, prefetcher=prefetcher,
                interval_steps=retier_interval, interval_s=retier_interval_s,
                decay=retier_decay, compact_every=retier_compact_every,
                artifact_dir=artifact_dir,
            )
            if fleet is not None:
                # join the fleet BEFORE traffic: a controller with learned
                # state warm-bootstraps this replica here, synchronously
                name = replica_name or f"replica-{len(fleet.replicas)}"
                fleet.register(name, daemon)
        server = ColdStartServer(model, tree, report, tiered=tiered, store=store,
                                 prefetcher=prefetcher, retier_daemon=daemon,
                                 artifact_dir=artifact_dir, admission=admission,
                                 kv_page_size=kv_page_size, kv_pages=kv_pages)
        if restore_from is not None:
            # warm restore (DESIGN.md §15.3): re-fault the donor's residency
            # set (in LRU order, through the arbiter make-room path) and arm
            # the predictor BEFORE the server admits traffic. Counted in the
            # upload phase — it is bytes moved as part of becoming ready.
            t_r = time.perf_counter()
            snap = (
                server_snapshot.load(restore_from)
                if isinstance(restore_from, str) else restore_from
            )
            server.restore_report = server_snapshot.restore(
                tiered, snap, prefetcher=prefetcher, artifact_dir=artifact_dir
            )
            report.upload_s += time.perf_counter() - t_r
            report.bytes_uploaded += server.restore_report.get("moved_bytes", 0)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    if compile_warm_set:
        t3 = time.perf_counter()
        p = server.live_params()
        for B, S in warm_shapes:
            pb, _ = model.prefill_batch_spec(B, S, multimodal=False)
            pb.pop("frames", None)
            pb.pop("image_embeds", None)
            fn = server.compiled_prefill(B, S)
            _ = fn.lower(p, _zeros_batch(pb)).compile()
            dfn = server.compiled_decode(B)
            cache = model.abstract_cache(B, S, multimodal=False)
            db, _ = model.decode_batch_spec(B)
            _ = dfn.lower(p, _abs_zeros(cache), _zeros_batch(db)).compile()
        report.compile_s = time.perf_counter() - t3
    return server


def _zeros_batch(spec: dict) -> dict:
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}


def _abs_zeros(tree: Any) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)
