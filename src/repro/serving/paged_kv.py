"""Paged KV-cache pool for the continuous-batching scheduler
(DESIGN.md §16.2).

The dense scheduler cache gives every slot ``max_seq`` positions whether
its request uses 10 tokens or 1000 — and the masked decode step streams
all of them. The paged layout carves the same capacity into fixed-size
pages owned by a global free list: a request is granted exactly the pages
its ``prompt + n_steps`` positions need at admission, holds them for its
lifetime, and returns them at retire (or failure — the scheduler's
failure paths free before the slot is reused). The decode kernel then
walks only occupied pages (``kernels.decode_attention.paged_decode_
attention``), so a slot's per-step KV bytes follow its actual length.

``PagePool`` is the host-side allocator: bookkeeping only (page ids,
no tensors), single-threaded by the same contract as the scheduler's
slot arrays — exactly one loop thread admits and retires. Exhaustion is
an *admission* signal: ``alloc`` fails atomically (no partial grant) and
the scheduler rejects the request with slot state untouched.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class PagePoolStats:
    allocs: int = 0            # successful per-request grants
    frees: int = 0             # per-request releases
    alloc_pages: int = 0       # pages handed out across all grants
    exhausted: int = 0         # failed grants (admission rejections)
    high_water_pages: int = 0  # peak concurrent pages in use

    def to_dict(self) -> dict:
        return dict(self.__dict__)


class PagePool:
    """Fixed pool of ``n_pages`` KV pages of ``page_size`` token positions,
    allocated per scheduler slot and freed wholesale at retire."""

    def __init__(self, n_pages: int, page_size: int, n_slots: int):
        if n_pages < 1 or page_size < 1 or n_slots < 1:
            raise ValueError(
                f"PagePool needs positive sizes, got n_pages={n_pages} "
                f"page_size={page_size} n_slots={n_slots}"
            )
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = int(n_slots)
        # LIFO free list: a just-freed request's pages are the next grant
        # (deterministic reuse, tested in tests/test_scheduler.py)
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._owned: dict[int, list[int]] = {}  # slot -> pages, logical order
        self.stats = PagePoolStats()

    # -- sizing ----------------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` cache positions (≥1: even a 1-token
        request owns a page)."""
        return max(1, -(-int(n_tokens) // self.page_size))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    # -- lifecycle --------------------------------------------------------------
    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Grant the pages ``n_tokens`` positions need to ``slot``.
        Atomic: on exhaustion nothing is granted and False returns (the
        admission rejection); a slot must be freed before re-granting."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages (free it first)")
        need = self.pages_for(n_tokens)
        if need > len(self._free):
            self.stats.exhausted += 1
            return False
        self._owned[slot] = [self._free.pop() for _ in range(need)]
        self.stats.allocs += 1
        self.stats.alloc_pages += need
        self.stats.high_water_pages = max(self.stats.high_water_pages, self.used_pages)
        return True

    def free(self, slot: int) -> int:
        """Return ``slot``'s pages to the free list (idempotent — the
        scheduler's failure paths may race retire bookkeeping). Returns the
        number of pages released."""
        pages = self._owned.pop(slot, None)
        if pages is None:
            return 0
        # LIFO: freed pages go back on top, preserving deterministic reuse
        self._free.extend(reversed(pages))
        self.stats.frees += 1
        return len(pages)

    def owned(self, slot: int) -> list[int]:
        return list(self._owned.get(slot, ()))

    # -- kernel-facing views ----------------------------------------------------
    def page_table(self, np_max: int | None = None) -> np.ndarray:
        """(n_slots, np_max) int32 physical-page table for the paged decode
        kernel: row s holds slot s's pages in logical order, tail-padded
        with the slot's last page (the kernel's DMA-elision convention) or
        0 for empty slots."""
        if np_max is None:
            np_max = max(1, -(-self.n_pages // max(self.n_slots, 1)))
            np_max = max(np_max, max((len(p) for p in self._owned.values()), default=1))
        table = np.zeros((self.n_slots, np_max), np.int32)
        for slot, pages in self._owned.items():
            row = (pages + [pages[-1]] * np_max)[:np_max]
            table[slot] = row
        return table

    # -- accounting (the roofline gate's achieved-bytes numerator) --------------
    def step_kv_positions(self, active_lens: dict[int, int]) -> int:
        """KV positions one paged decode step streams: per active slot, its
        occupied pages × page_size (whole pages move — the honest number,
        not the masked-length one)."""
        total = 0
        for slot, n in active_lens.items():
            pages = self._owned.get(slot)
            n_pages = len(pages) if pages else self.pages_for(n)
            # only pages holding any of the first n positions stream
            total += min(n_pages, self.pages_for(n)) * self.page_size
        return total

    def assert_consistent(self) -> None:
        """Every page is exactly once in the free list or one slot's grant."""
        seen = list(self._free) + [p for ps in self._owned.values() for p in ps]
        if sorted(seen) != list(range(self.n_pages)):
            raise AssertionError(
                f"page books corrupt: {len(self._free)} free + "
                f"{sum(len(p) for p in self._owned.values())} owned != {self.n_pages}"
            )
