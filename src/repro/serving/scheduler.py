"""Continuous-batching request scheduler over the on-demand engine
(DESIGN.md §9).

``GenerationEngine.generate()`` serves exactly one batch synchronously —
one request's cold-unit fault-in stalls the whole host. The scheduler
turns the same fault-in/pin/prefetch machinery into a serving loop:

  * **slots** — a fixed ``max_batch`` of decode lanes over ONE compiled
    masked-decode executable (``ColdStartServer.compiled_decode_masked``).
    Per slot: the owning request, its token position, its last emitted
    token; the done/free state is the ``active`` mask fed to the compiled
    step. Inactive rows ride the batch as pad lanes: their routing never
    reaches the usage masks (so a free slot can never fault a unit in),
    and whatever garbage they write to their own cache row is overwritten
    wholesale at the slot's next admission — pad lanes cost compute,
    never correctness.
  * **admission** — between decode steps, queued prompts fill free slots:
    prefill runs on its own compiled (1, S) shape, then the prefill cache
    is grafted into the slot row of the batched decode cache
    (``_graft_slot_cache``). Over-length requests are *rejected* at
    admission (``Request.error``), never raised out of the loop.
  * **union fault handling** — each decode step issues one
    ``ensure(pin=True)`` over the union of all active slots' vocab
    row-groups, and one expert fault/retry loop over the union of routed
    expert misses. A request whose units are cold adds latency to the
    *step*, not a serialization point per request — all slots' misses
    load in a single offset-sorted batch.
  * **fairness** — admission is strictly FIFO (arrival order), every
    active slot advances exactly one token per step, and predictive hints
    are round-robin-merged across slots (``core.prefetch.merge_hints``)
    so one request's long tail can't starve another's next-step units.
  * **paged KV lifecycle** (DESIGN.md §16.2) — a ``PagePool`` carves the
    decode-cache capacity into fixed-size pages: admission grants each
    request the pages its ``prompt + n_steps`` positions need (atomic —
    on exhaustion the request is *rejected* with slot state untouched),
    retire and both failure paths return them, and per-step accounting
    (``kv_tokens_dense`` vs ``kv_tokens_paged``) feeds the roofline
    gate's achieved-vs-max-shape KV bytes. The default pool exactly
    covers ``max_batch × max_seq``, so page exhaustion is impossible and
    admission decisions are byte-identical to the pre-paging scheduler.

Greedy outputs are per-slot identical to running each request alone
through ``generate()`` (tested in tests/test_scheduler.py): decode rows
are computationally independent, dropless MoE dispatch is per-token
exact, and admission rebuilds the slot's cache row from scratch.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prefetch import merge_hints
from repro.serving.engine import GenerationEngine, RequestStats
from repro.serving.paged_kv import PagePool
from repro.utils.tree import flatten_with_paths, tree_from_flat


@dataclass
class Request:
    """One generation request moving through the scheduler."""

    rid: int
    tokens: np.ndarray  # (S,) int32 prompt
    n_steps: int
    submitted_t: float = 0.0
    admitted_t: float = 0.0
    first_token_t: float = 0.0
    finished_t: float = 0.0
    # SLO admission (DESIGN.md §15.2): seconds after submit by which the
    # LAST token must land (None → no deadline), and a tie-breaking
    # priority (higher first under burst re-ordering). Both are ignored
    # by the default FIFO policy.
    deadline_s: Optional[float] = None
    priority: int = 0
    out: list = field(default_factory=list)  # emitted token ids
    stats: RequestStats = field(default_factory=RequestStats)
    error: Optional[str] = None
    _done: threading.Event = field(default_factory=threading.Event, repr=False)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def finish(self, error: Optional[str] = None) -> None:
        self.error = error
        self.finished_t = time.perf_counter()
        self._done.set()

    @property
    def output(self) -> np.ndarray:
        return np.asarray(self.out, np.int32)

    @property
    def latency_s(self) -> float:
        """Submit → last token (0 until finished)."""
        return max(0.0, self.finished_t - self.submitted_t)

    @property
    def ttft_s(self) -> float:
        """Submit → first token (prefill wait included)."""
        return max(0.0, self.first_token_t - self.submitted_t)

    @property
    def deadline_t(self) -> Optional[float]:
        """Absolute perf_counter deadline (None without one)."""
        if self.deadline_s is None:
            return None
        return self.submitted_t + self.deadline_s

    @property
    def shed(self) -> bool:
        """True when an SLO policy dropped this request unserved."""
        return self.error is not None and self.error.startswith("shed:")


class RequestQueue:
    """Thread-safe FIFO of pending requests.

    Arrival order IS the admission order — the scheduler's fairness
    contract (DESIGN.md §9) starts here. ``submit`` is safe from any
    thread (a traffic generator, an RPC handler); the scheduler thread
    pops."""

    def __init__(self) -> None:
        self._q: deque[Request] = deque()
        self._lock = threading.Lock()
        self._next_rid = 0

    def submit(self, tokens, n_steps: int, *, deadline_s: Optional[float] = None,
               priority: int = 0) -> Request:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            req = Request(rid, tokens, int(n_steps),
                          submitted_t=time.perf_counter(),
                          deadline_s=deadline_s, priority=int(priority))
            self._q.append(req)
        return req

    def pop(self) -> Optional[Request]:
        with self._lock:
            return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


class AdmissionPolicy:
    """Pluggable admission decision for the scheduler (DESIGN.md §15.2).

    Each admission round the scheduler calls ``select(queue, free, now,
    validate)``: the policy may pop from the thread-safe queue and must
    return ``(admit, drop)`` — at most ``free`` requests to admit this
    round, plus ``(request, kind, error)`` triples to retire unserved
    (``kind`` is ``"rejected"`` for structurally invalid requests,
    ``"shed"`` for load/deadline drops; ``error`` becomes
    ``Request.error``). ``validate(req)`` returns the canonical rejection
    message or None. A policy may hold popped-but-unadmitted requests in
    an internal backlog; it then reports them via ``pending()`` so the
    scheduler's idle/run logic still sees them as outstanding work.
    ``note_prefill``/``note_step`` feed it observed service times.
    """

    def select(self, queue: RequestQueue, free: int, now: float, validate):
        raise NotImplementedError

    def pending(self) -> int:
        return 0

    def note_prefill(self, seconds: float) -> None:
        pass

    def note_step(self, seconds: float, n_active: int) -> None:
        pass


class FIFOAdmission(AdmissionPolicy):
    """The default: strict arrival order, no deadlines, never sheds —
    byte-identical admission decisions to the pre-policy scheduler (the
    §9 fairness contract; parity-tested by rq5/rq7/rq8)."""

    def select(self, queue: RequestQueue, free: int, now: float, validate):
        admit: list[Request] = []
        drop: list[tuple[Request, str, str]] = []
        while len(admit) < free:
            req = queue.pop()
            if req is None:
                break
            err = validate(req)
            if err is not None:
                # reject, don't crash: the loop must survive bad requests
                drop.append((req, "rejected", err))
                continue
            admit.append(req)
        return admit, drop


class SLOAdmission(AdmissionPolicy):
    """Deadline/queue-depth-aware admission (DESIGN.md §15.2).

    Opt-in via ``cold_start(admission=...)`` or the scheduler's
    ``admission=`` kwarg; the FIFO default is untouched. Three behaviors
    replace tail-latency-by-timeout with shed-at-admission:

      * **shed-on-hopeless** — a request whose projected finish already
        exceeds its deadline is dropped *before* any prefill/decode is
        spent on it, with ``error="shed: ..."``. The projection is
        slot-granular: ranked-ahead work fills the host's admission
        slots in waves, each wave holding its slot for a full decode
        residence, so a request ``w`` waves deep projects ``now +
        prefill_est + (1 + w) × n_steps × step_est``. Estimates are
        EMAs of observed service times, so projections track the live
        fault/decode cost.
      * **priority re-order under burst** — the backlog admits by
        (priority desc, deadline asc, arrival), so when a burst
        overflows the slots, urgent work jumps the queue; with equal
        priorities and no deadlines the order degenerates to FIFO.
      * **bounded backlog wait** — requests the round couldn't admit
        stay in the policy's backlog (counted by ``pending()``) and are
        re-projected every round: one that becomes hopeless while
        queued is shed then, not after burning a slot.

    ``default_deadline_s`` applies to requests submitted without one
    (None → such requests are never shed).
    """

    def __init__(
        self,
        *,
        default_deadline_s: Optional[float] = None,
        step_est_s: float = 2e-3,      # decode-step EMA seed (refined online)
        prefill_est_s: float = 10e-3,  # prefill EMA seed
        ema: float = 0.2,              # weight of each new observation
    ):
        self.default_deadline_s = default_deadline_s
        self.ema = float(ema)
        self._step_est = float(step_est_s)
        self._prefill_est = float(prefill_est_s)
        self._backlog: list[Request] = []
        self._slots = 1  # widest admission round seen ≈ the host's slot count
        self.shed_total = 0

    def pending(self) -> int:
        return len(self._backlog)

    def note_prefill(self, seconds: float) -> None:
        self._prefill_est += self.ema * (seconds - self._prefill_est)

    def note_step(self, seconds: float, n_active: int) -> None:
        self._step_est += self.ema * (seconds - self._step_est)

    def _deadline_t(self, req: Request) -> Optional[float]:
        if req.deadline_s is not None:
            return req.submitted_t + req.deadline_s
        if self.default_deadline_s is not None:
            return req.submitted_t + self.default_deadline_s
        return None

    def select(self, queue: RequestQueue, free: int, now: float, validate):
        drop: list[tuple[Request, str, str]] = []
        self._slots = max(self._slots, free)
        # drain arrivals into the backlog (validating on entry, so a bad
        # request is retired this round whether or not slots are free)
        while True:
            req = queue.pop()
            if req is None:
                break
            err = validate(req)
            if err is not None:
                drop.append((req, "rejected", err))
                continue
            self._backlog.append(req)
        # burst re-order: urgent first, then earliest deadline, then arrival
        def rank(r: Request):
            dt = self._deadline_t(r)
            return (-r.priority, dt if dt is not None else float("inf"), r.rid)
        self._backlog.sort(key=rank)
        kept: list[Request] = []
        for r in self._backlog:
            dt = self._deadline_t(r)
            if dt is not None:
                # slot-granular projection: ranked-ahead work fills the
                # slots in waves, each holding its slot for a full decode
                # residence; mid-decode rounds (free == 0) cost one more
                waves = len(kept) // self._slots + (1 if free == 0 else 0)
                projected = (now + self._prefill_est
                             + (1 + waves) * r.n_steps * self._step_est)
                if projected > dt:
                    self.shed_total += 1
                    drop.append((r, "shed", (
                        f"shed: projected finish +{projected - r.submitted_t:.3f}s "
                        f"exceeds deadline {dt - r.submitted_t:.3f}s "
                        f"(backlog={len(self._backlog)}, "
                        f"step_est={self._step_est * 1e3:.2f}ms)"
                    )))
                    continue
            kept.append(r)
        admit, self._backlog = kept[:free], kept[free:]
        return admit, drop


@dataclass
class SchedulerStats:
    """Aggregate loop accounting (per-request numbers live on each
    ``Request.stats``; step-shared costs — the union fault, the batched
    decode — are only meaningful at the loop level)."""

    steps: int = 0          # batched decode steps executed
    admitted: int = 0
    rejected: int = 0
    shed: int = 0           # SLO-policy drops (never under FIFO)
    completed: int = 0
    failed: int = 0         # admitted requests killed by a decode-step failure
    decode_s: float = 0.0
    fault_s: float = 0.0
    faulted_units: int = 0
    faulted_bytes: int = 0
    decode_retries: int = 0
    max_active: int = 0     # high-water concurrent slots
    # paged-KV accounting (DESIGN.md §16.2): cache positions the masked
    # decode streams at max shape vs. what the paged layout would stream
    # (occupied pages of active slots only) — the roofline gate's numbers
    kv_tokens_dense: int = 0
    kv_tokens_paged: int = 0
    kv_pages_high_water: int = 0

    def to_dict(self) -> dict:
        return dict(self.__dict__)


class ContinuousBatchingScheduler:
    """Slot-based continuous batching over ``GenerationEngine`` primitives.

    Single-consumer: exactly one thread drives ``step()``/``run()`` (the
    serving loop); any thread may ``submit()``. The decode cache, slot
    arrays, and stats are owned by the loop thread — the underlying
    ``TieredParams`` residency layer provides its own locking for the
    fault/prefetch traffic the loop generates.
    """

    def __init__(
        self,
        engine: GenerationEngine,
        *,
        max_batch: int = 4,
        queue: Optional[RequestQueue] = None,
        admission: Optional[AdmissionPolicy] = None,
        kv_page_size: Optional[int] = None,
        kv_pages: Optional[int] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.server = engine.server
        self.model = engine.model
        self.max_batch = max_batch
        self.queue = queue if queue is not None else RequestQueue()
        # admission policy (DESIGN.md §15.2): explicit kwarg wins, then the
        # server's cold_start(admission=...) default, then strict FIFO
        self.admission = (
            admission
            if admission is not None
            else getattr(self.server, "admission", None) or FIFOAdmission()
        )
        # paged-KV pool (DESIGN.md §16.2): explicit kwargs win, then the
        # server's cold_start(kv_page_size=/kv_pages=) defaults; the pool
        # defaults to exactly max_batch × max_seq worth of pages, where
        # exhaustion is impossible and admission is byte-identical
        ps = kv_page_size or getattr(self.server, "kv_page_size", None) or 16
        per_slot = -(-engine.max_seq // ps)
        n_pages = kv_pages or getattr(self.server, "kv_pages", None) or max_batch * per_slot
        self.page_pool = PagePool(n_pages, ps, max_batch)
        self.stats = SchedulerStats()
        self._slots: list[Optional[Request]] = [None] * max_batch
        self._pos = np.zeros(max_batch, np.int32)       # next decode position
        self._last_tok = np.zeros(max_batch, np.int32)  # token feeding the next step
        self._caches = self.model.init_cache(max_batch, engine.max_seq, multimodal=False)
        self._decode = self.server.compiled_decode_masked(max_batch)
        # one jitted graft for every (group size, prompt len) signature;
        # donating the batched cache lets XLA update the slot rows in place
        # instead of copying every leaf per admission
        self._graft = jax.jit(_graft_slot_cache, donate_argnums=(0,))

    def warm_compile(self) -> None:
        """Pre-compile the masked decode at the slot batch shape so the
        first traffic step serves instead of compiling (admission prefills
        and grafts still compile per prompt length on first use)."""
        model, B = self.model, self.max_batch
        cache = model.abstract_cache(B, self.engine.max_seq, multimodal=False)
        db, _ = model.decode_masked_batch_spec(B)
        # lower() takes the ShapeDtypeStruct trees directly — materializing
        # a zero cache here would transiently double device cache memory
        self._decode.lower(self.server.live_params(), cache, db).compile()

    # -- submission ------------------------------------------------------------
    def submit(self, tokens, n_steps: int) -> Request:
        """Enqueue one prompt. Decoding is greedy (argmax) — the
        sequential-equivalence contract is only defined for greedy."""
        return self.queue.submit(tokens, n_steps)

    @property
    def _tracing(self) -> bool:
        """True when a live AccessTrace would record request attribution."""
        tiered = self.server.tiered
        return tiered is not None and tiered.trace is not None

    @property
    def active(self) -> list[int]:
        return [i for i, r in enumerate(self._slots) if r is not None]

    @property
    def idle(self) -> bool:
        # the policy's backlog is outstanding work too: an SLO policy may
        # have drained the queue into itself without admitting everything
        return (not self.active and len(self.queue) == 0
                and self.admission.pending() == 0)

    def _validate(self, req: Request) -> Optional[str]:
        """Canonical structural check; the policy-independent rejection
        contract (message unchanged from the pre-policy scheduler)."""
        S = int(req.tokens.size)
        if S == 0 or S + req.n_steps > self.engine.max_seq or req.n_steps < 1:
            return (
                f"rejected: prompt {S} + {req.n_steps} steps exceeds "
                f"max_seq={self.engine.max_seq} (or is empty)"
            )
        return None

    # -- admission ---------------------------------------------------------------
    def _admit(self) -> int:
        """Fill free slots per the admission policy (FIFO by default).
        Same-length prompts admitted in the same round share ONE batched
        prefill (the step primitives are batch-agnostic, so their
        vocab/expert faults union for free); the resulting cache rows are
        grafted into the slots in a single jitted call. Returns the
        number of requests admitted."""
        free = [i for i, r in enumerate(self._slots) if r is None]
        to_admit, dropped = self.admission.select(
            self.queue, len(free), time.perf_counter(), self._validate
        )
        for req, kind, err in dropped:
            if kind == "shed":
                self.stats.shed += 1
            else:
                self.stats.rejected += 1
            req.finish(error=err)
        picked: list[tuple[int, Request]] = [
            (free[i], req) for i, req in enumerate(to_admit[: len(free)])
        ]
        # paged-KV grant (§16.2): each request owns the pages its
        # prompt + n_steps positions need before any prefill is spent on
        # it. Exhaustion is an admission rejection with slot state
        # untouched — the loop keeps serving, the submitter sees an error.
        granted: list[tuple[int, Request]] = []
        for slot, req in picked:
            need = int(req.tokens.size) + req.n_steps
            if not self.page_pool.alloc(slot, need):
                self.stats.rejected += 1
                req.finish(error=(
                    f"rejected: kv page pool exhausted "
                    f"(need {self.page_pool.pages_for(need)} pages, "
                    f"{self.page_pool.free_pages} free of {self.page_pool.n_pages})"
                ))
                continue
            granted.append((slot, req))
        picked = granted

        admitted = 0
        hints: list[list[str]] = []
        observed: list[str] = []
        by_request: dict[int, list[str]] = {}
        # group same-length prompts (everything picked is admitted this
        # round, so grouping cannot reorder anyone past anyone else)
        groups: dict[int, list[tuple[int, Request]]] = {}
        for slot, req in picked:
            groups.setdefault(req.tokens.size, []).append((slot, req))
        for S, grp in groups.items():
            slots = [s for s, _ in grp]
            reqs = [r for _, r in grp]
            now = time.perf_counter()
            for r in reqs:
                r.admitted_t = now
            shared = RequestStats()
            try:
                toks = jnp.asarray(np.stack([r.tokens for r in reqs]))
                logits, caches, expert_keys = self.engine.prefill_step(
                    toks, shared, hint=False
                )
            except Exception as e:
                # a failed fault-in must not kill the loop (or leave the
                # submitters waiting forever) — fail the group's requests,
                # return their slots, keep serving
                self.stats.failed += len(reqs)
                for s, r in grp:
                    self.page_pool.free(s)  # a failed request leaks no pages
                    r.finish(error=f"prefill failed: {e!r}")
                continue
            self.admission.note_prefill(shared.prefill_s + shared.fault_s)
            self._caches = self._graft(self._caches, caches, jnp.asarray(slots, jnp.int32))
            lg = np.asarray(logits)
            # per-request attribution (§12.3): each prompt's own row-groups;
            # expert keys are exact only when the prefill wasn't shared.
            # Skipped entirely when no trace is attached — the common
            # tracing-off path pays nothing for it.
            if self._tracing:
                for r in reqs:
                    by_request[r.rid] = self.engine.row_keys_for(r.tokens) + (
                        list(expert_keys) if len(reqs) == 1 else []
                    )
            for i, (slot, req) in enumerate(grp):
                # group costs are shared: every member waited out the batch
                req.stats.prefill_s += shared.prefill_s
                req.stats.fault_s += shared.fault_s
                req.stats.prefill_retries += shared.prefill_retries
                req.stats.faulted_units += shared.faulted_units
                req.stats.faulted_bytes += shared.faulted_bytes
                tok = int(lg[i].argmax())
                req.out.append(tok)
                req.stats.steps = 1  # the prefill-produced token
                req.first_token_t = time.perf_counter()
                self._pos[slot] = S
                self._last_tok[slot] = tok
                self._slots[slot] = req
                self.stats.admitted += 1
                admitted += 1
                hints.append(self.engine.topk_row_hints(lg[i]))
                if len(req.out) >= req.n_steps:  # single-token request
                    self._retire(slot)
            if expert_keys:
                hints.append(list(expert_keys))
            observed += self.engine.row_keys_for(
                np.concatenate([r.tokens for r in reqs])
            ) + list(expert_keys)
        self._emit_hints(hints, observed=observed, by_request=by_request)
        return admitted

    def _retire(self, slot: int) -> None:
        req = self._slots[slot]
        assert req is not None
        self._slots[slot] = None
        self._last_tok[slot] = 0
        self._pos[slot] = 0
        self.page_pool.free(slot)  # pages return at retire, ready for reuse
        self.stats.completed += 1
        req.finish()

    def _emit_hints(self, per_slot_hints: list[list[str]],
                    observed: list[str] = (),
                    by_request: Optional[dict] = None) -> None:
        """Feed the prefetcher — first the units this step *actually*
        accessed (``observe`` expands them through the profile-trained
        predictor into ahead-of-schedule hints — DESIGN.md §11.3), then
        the round-robin-merged per-slot next-step hints — and tag the
        live trace with per-request attribution (``by_request``: rid →
        the keys THAT request accessed this step). The unioned demand
        batch already landed in the trace via ``ensure()``; the tags add
        the coincidence-free association signal the replanner and the
        daemon's predictor refresh prefer (DESIGN.md §12.3). Requests
        that finished this step are recorded FIRST (their final step's
        transitions matter too), then their chain state is dropped so a
        freed slot's next occupant never links to them."""
        if by_request:
            tiered = self.server.tiered
            if tiered is not None:
                live = {r.rid for r in self._slots if r is not None}
                for rid, keys in by_request.items():
                    if keys:
                        tiered.record_request(rid, keys)
                    if rid not in live:
                        tiered.end_request(rid)
        pf = self.engine.prefetcher
        if pf is None:
            return
        if observed:
            pf.observe(observed)
        merged = merge_hints(*per_slot_hints)
        if merged:
            pf.hint(merged)

    # -- the serving loop --------------------------------------------------------
    def step(self) -> bool:
        """Admit new work, then advance every active slot one token with a
        single masked decode over the union of their faults. Returns True
        if anything happened (admission or decode)."""
        admitted = self._admit()
        active = self.active
        self.stats.max_active = max(self.stats.max_active, len(active))
        if not active:
            # still a step boundary: the re-tier daemon may tick on
            # wall-clock cadence even while the queue is drained (§12)
            self.engine.tick_retier(steps=0)
            return admitted > 0

        mask = np.zeros(self.max_batch, bool)
        mask[active] = True
        dbatch = {
            "tokens": jnp.asarray(self._last_tok[:, None]),
            "pos": jnp.asarray(self._pos),
            "active": jnp.asarray(mask),
        }
        # union fault handling: ONE pinned ensure over every active slot's
        # row-groups + one expert retry loop over the union of misses
        step_stats = RequestStats()
        try:
            logits, self._caches, expert_keys = self.engine.decode_once(
                self._decode, self._caches, dbatch, step_stats,
                prefault_tokens=self._last_tok[active], hint=False,
            )
        except Exception as e:
            # same contract as admission: a failed step fault-in must not
            # kill the loop or leave the active slots' submitters waiting
            # forever — fail those requests, return their slots, keep
            # serving the queue
            self.stats.failed += len(active)
            tiered = self.server.tiered
            for i in active:
                req = self._slots[i]
                self._slots[i] = None
                self._last_tok[i] = 0
                self._pos[i] = 0
                self.page_pool.free(i)  # failed slots leak no pages
                if tiered is not None:
                    # failed requests never reach _emit_hints — drop their
                    # trace chain state here or it leaks forever (§12.3)
                    tiered.end_request(req.rid)
                req.finish(error=f"decode step failed: {e!r}")
            return True
        self.stats.decode_s += step_stats.decode_s
        self.stats.fault_s += step_stats.fault_s
        self.stats.faulted_units += step_stats.faulted_units
        self.stats.faulted_bytes += step_stats.faulted_bytes
        self.stats.decode_retries += step_stats.decode_retries
        self.stats.steps += 1
        self.admission.note_step(step_stats.decode_s + step_stats.fault_s, len(active))
        # paged-KV accounting (§16.2): the masked decode streams the full
        # (max_batch, max_seq) cache; the paged layout would stream only
        # the active slots' occupied pages. The roofline gate compares.
        self.stats.kv_tokens_dense += self.max_batch * self.engine.max_seq
        self.stats.kv_tokens_paged += self.page_pool.step_kv_positions(
            {i: int(self._pos[i]) + 1 for i in active}
        )
        self.stats.kv_pages_high_water = self.page_pool.stats.high_water_pages

        # units this step demand-accessed: the active slots' embed
        # row-groups plus every routed expert (resident ones included —
        # post-retier they key most of the transition table)
        observed = self.engine.row_keys_for(self._last_tok[active]) + list(expert_keys)
        # per-request attribution (§12.3), captured before the token
        # updates below overwrite _last_tok: each slot's own row-groups;
        # union-detected experts are exact only with a single active slot.
        # Skipped when no trace is attached (nothing would record it).
        by_request = {
            self._slots[i].rid: self.engine.row_keys_for(self._last_tok[i:i + 1]) + (
                list(expert_keys) if len(active) == 1 else []
            )
            for i in active
        } if self._tracing else {}

        lg = np.asarray(logits)
        hints: list[list[str]] = []
        for i in active:
            req = self._slots[i]
            tok = int(lg[i].argmax())
            req.out.append(tok)
            req.stats.steps += 1
            self._last_tok[i] = tok
            self._pos[i] += 1
            if len(req.out) >= req.n_steps:
                self._retire(i)
            else:
                hints.append(self.engine.topk_row_hints(lg[i]))
        if expert_keys:
            hints.append(list(expert_keys))
        self._emit_hints(hints, observed=observed, by_request=by_request)
        # the step is fully over (pins released, outputs materialized):
        # the ONLY place the serving loop advances the re-tier daemon
        self.engine.tick_retier()
        return True

    def run(self, *, max_steps: Optional[int] = None) -> None:
        """Drive the loop until the queue is empty and every slot is free
        (or ``max_steps`` decode steps have run)."""
        steps = 0
        while not self.idle:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break

    def serve_forever(self, stop: threading.Event, poll_s: float = 0.002) -> None:
        """Loop until ``stop`` is set, sleeping briefly when idle — the
        threaded form used by the traffic benchmark and launcher."""
        while not stop.is_set():
            if not self.step():
                time.sleep(poll_s)


def _graft_slot_cache(big: Any, small: Any, slots: jax.Array) -> Any:
    """Write an admission group's prefill cache (B=k) into slot rows
    ``slots`` ((k,) int32) of the batched decode cache.

    Each slot row is rebuilt from zeros (matching ``Model.init_cache``)
    with the prefill prefix written along the sequence axis — exactly the
    sequential path's ``_graft_prefill_cache`` semantics, applied per
    batch row. Scanned-group leaves are (n_groups, B, ...): batch is axis
    1 there, axis 0 everywhere else. Jit-compiled by the scheduler (one
    signature per group size × prompt length) with the big cache donated,
    so steady-state admission is a handful of in-place row updates, not a
    full-cache copy."""
    big_flat = dict(flatten_with_paths(big))
    out = dict(big_flat)
    for path, s in flatten_with_paths(small):
        b = out[path]
        s = jnp.asarray(s)
        ax = 1 if path.startswith("groups.") else 0
        row_shape = b.shape[:ax] + b.shape[ax + 1:]
        for i in range(s.shape[ax]):
            src = jax.lax.index_in_dim(s, i, axis=ax, keepdims=False).astype(b.dtype)
            if src.shape == row_shape:
                row = src  # carry-state leaf (mlstm C/n/m, lru, conv): full copy
            else:
                idx = tuple(slice(0, d) for d in src.shape)
                row = jnp.zeros(row_shape, b.dtype).at[idx].set(src)
            b = jax.lax.dynamic_update_index_in_dim(b, row, slots[i], ax)
        out[path] = b
    return tree_from_flat(out)
