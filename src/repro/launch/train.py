"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop (repro.training) on whatever devices
exist — reduced configs on the CPU container, full configs on a real
TPU slice (same code path; the mesh adapts). Checkpoint/restart works
across invocations: rerunning the command resumes from the latest
committed step.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_reduced
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.zoo import build_model
from repro.optim import AdamWConfig
from repro.training import TrainConfig, Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {model.num_params():,} params "
          f"({model.active_params():,} active) on {len(jax.devices())} devices")

    data = SyntheticTokenPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch, seed=args.seed)
    )
    tcfg = TrainConfig(
        num_steps=args.steps,
        save_every=args.save_every,
        micro_batches=args.micro_batches,
        adamw=AdamWConfig(lr=args.lr),
        seed=args.seed,
    )
    trainer = Trainer(model, tcfg, data, f"{args.ckpt_dir}/{cfg.name}")
    result = trainer.run()
    print(f"[train] done @ step {result.final_step}; "
          f"loss {result.losses[0]:.4f} -> {result.losses[-1]:.4f}; "
          f"resumed_from={result.restored_from}; stragglers={len(result.flagged_steps)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
