"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before the first jax init, and
tests/benchmarks must keep seeing 1 device.

Mesh geometry (per assignment):
  single-pod : (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Axis ordering puts "pod" outermost so every cross-pod collective factors
into a hierarchical (ICI-inner, DCN-outer) schedule by construction; the
logical-axis rules (repro.sharding) compose "batch" over ("pod", "data").
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices exist (CPU smoke tests).

    Fails with an actionable message when the requested geometry wants
    more devices than the platform exposes — otherwise jax surfaces an
    opaque reshape error from deep inside ``make_mesh``. On CPU the fix
    is the dry-run's trick: set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* the
    first jax call."""
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data}, model={model}")
    have = jax.device_count()
    if data * model > have:
        raise ValueError(
            f"debug mesh ({data}x{model}) needs {data * model} devices but only "
            f"{have} exist; set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{data * model} before the first jax init (see launch/dryrun.py)"
        )
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_label(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
