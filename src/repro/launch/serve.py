"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

The FaaSLight pipeline end-to-end: analyze → build two-tier artifact →
timed cold start (before / after1 / after2) → serve a batch of generation
requests through the on-demand engine. This is the paper's experiment
harness in CLI form (benchmarks/bench_rq*.py drive the same path).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.core import (
    DeploymentProfile,
    analyze,
    build_artifact,
    write_monolithic,
)
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.zoo import build_model
from repro.optim import init_adamw
from repro.serving import GenerationEngine, cold_start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="after2", choices=["before", "after1", "after2"])
    ap.add_argument("--artifact-dir", default="artifacts")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-steps", type=int, default=8)
    ap.add_argument("--resident-experts", type=int, default=1)
    ap.add_argument("--hot-vocab", type=float, default=0.25)
    ap.add_argument("--policy", default="stats", choices=["strict", "stats", "full"],
                    help="residency budget preset (DESIGN.md §4.2); also shapes the profile")
    ap.add_argument("--device-budget-bytes", type=int, default=0,
                    help="override the preset's tier-1 device budget (0 = preset default)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async prefetcher even where the preset enables it")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(collect_moe_usage=cfg.moe is not None)
    model = build_model(cfg)
    outdir = os.path.join(args.artifact_dir, cfg.name)

    if args.policy == "strict":
        profile = DeploymentProfile(resident_experts=0, hot_vocab_fraction=0.0,
                                    min_tier1_bytes=1 << 14, vocab_row_group=max(64, cfg.vocab_size // 16))
        stats = None
    elif args.policy == "full":
        profile = DeploymentProfile(resident_experts=-1, hot_vocab_fraction=1.0)
        stats = None
    else:  # stats
        profile = DeploymentProfile(
            resident_experts=args.resident_experts,
            hot_vocab_fraction=args.hot_vocab,
            min_tier1_bytes=1 << 14,
            vocab_row_group=max(64, cfg.vocab_size // 16),
        )
        pipe = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 128, 8))
        stats = pipe.vocab_row_stats(row_group=profile.vocab_row_group)

    print(f"[serve] analyzing {cfg.name} under profile {profile.name}/{args.policy}")
    result = analyze(model, profile, hot_units_stats=stats, trace_B=1, trace_S=32)
    print("[serve] plan:", json.dumps(result.summary(), default=str)[:400])

    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    os.makedirs(outdir, exist_ok=True)
    if args.mode in ("before", "after1"):
        write_monolithic({"params": params, "opt_state": {"m": opt.m, "v": opt.v}},
                         outdir, pruned=args.mode == "after1")
    else:
        build_artifact(params, result, outdir)

    server = cold_start(model, outdir, result if args.mode == "after2" else None,
                        mode=args.mode, warm_shapes=((args.batch, args.prompt_len),),
                        residency=args.policy if args.mode == "after2" else None,
                        device_budget_bytes=args.device_budget_bytes or None,
                        prefetch=False if args.no_prefetch else None)
    print(f"[serve] cold start ({args.mode}):", json.dumps(server.report.to_dict(), default=float))

    engine = GenerationEngine(server, max_seq=args.prompt_len + args.gen_steps + 8)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)
    out, stats_r = engine.generate(prompts, args.gen_steps)
    print(f"[serve] generated {out.shape}; prefill={stats_r.prefill_s*1e3:.1f}ms "
          f"decode={stats_r.decode_s*1e3:.1f}ms faults={stats_r.faulted_units} "
          f"({stats_r.faulted_bytes/2**20:.1f}MiB, {stats_r.fault_s*1e3:.1f}ms)")
    if server.tiered is not None:
        ts = server.tiered.stats
        budget = server.tiered.residency.budget_bytes
        print(f"[serve] resident fraction: {server.tiered.resident_fraction():.3f}; "
              f"resident {server.tiered.resident_bytes:,}B"
              + (f" / budget {budget:,}B" if budget else " (no budget)"))
        print(f"[serve] prefetch hit rate {ts.prefetch_hit_rate:.2f}; "
              f"evictions {ts.evictions}; refaults {ts.refaults}; "
              f"stall p99 {ts.stall_percentile(99)*1e3:.2f}ms")
    server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
