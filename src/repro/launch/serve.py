"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

The FaaSLight pipeline end-to-end: analyze → build two-tier artifact →
timed cold start (before / after1 / after2) → serve generation requests
through the on-demand engine. This is the paper's experiment harness in
CLI form (benchmarks/bench_rq*.py drive the same path).

Two request modes:
  * one-shot (default): a single batched ``GenerationEngine.generate()``;
  * traffic (``--concurrency N``): N continuous-batching slots served by
    the scheduler (DESIGN.md §9), with ``--requests`` prompts arriving
    open-loop at ``--arrival-rate`` req/s (0 = all at once), reporting
    throughput and per-request p50/p99 latency. Exits nonzero if any
    request failed or never finished.

Profile → re-tier → re-serve (DESIGN.md §11): ``--profile-out t.json``
records the demand-access trace of this serving run (profile with
``--no-prefetch`` so the trace sees every fault); a later run with
``--retier-from t.json`` replans the tier split from the trace, rewrites
the artifact next to the original (``<artifact>/<arch>-retier``), and
arms the prefetcher with the trace's learned unit→next-unit predictor.

Online re-tiering (DESIGN.md §12): ``--retier-online`` replaces that
restart cycle with a live daemon — the serving loop ticks it every
``--retier-interval`` steps; each tick merges the newest trace window
into a ``--retier-decay``-weighted history, replans, and applies the
hot set to the running server (promote = prefetch preload, demote =
eviction). ``--retier-compact-every N`` additionally rewrites the
artifact every N applications so future cold starts boot the adapted
hot set.

Fleet federation (DESIGN.md §14): ``--fleet N`` serves the one-shot
workload through N in-process replicas sharing one ``FleetController``
— each replica's daemon contributes its trace window at every
``fleet.sync()``, the controller replans ONCE from the federated
history, and pushes the residency overlay back to every replica, so a
hot-set shift one replica sees pre-warms all of them.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import clean_partials
from repro.configs import get_config, get_reduced
from repro.core import (
    AccessTrace,
    DeploymentProfile,
    FleetController,
    HostArbiter,
    TransitionPredictor,
    analyze,
    build_artifact,
    replan_from_trace,
    retier_artifact,
    write_monolithic,
)
from repro.core import snapshot as server_snapshot
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.launch.mesh import make_debug_mesh
from repro.models.zoo import build_model
from repro.optim import init_adamw
from repro.serving import (
    ContinuousBatchingScheduler,
    GenerationEngine,
    SLOAdmission,
    cold_start,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="after2", choices=["before", "after1", "after2"])
    ap.add_argument("--artifact-dir", default="artifacts")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-steps", type=int, default=8)
    ap.add_argument("--resident-experts", type=int, default=1)
    ap.add_argument("--hot-vocab", type=float, default=0.25)
    ap.add_argument("--policy", default="stats", choices=["strict", "stats", "full"],
                    help="residency budget preset (DESIGN.md §4.2); also shapes the profile")
    ap.add_argument("--device-budget-bytes", type=int, default=0,
                    help="override the preset's tier-1 device budget (0 = preset default)")
    ap.add_argument("--host-budget-bytes", type=int, default=0,
                    help="govern residency through a HostArbiter with this "
                         "host-wide device budget (DESIGN.md §13) instead of a "
                         "private per-model budget — the single-tenant form of "
                         "the multi-model pool benchmarks/bench_rq9_zoo.py "
                         "exercises (after2 only; 0 = off)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async prefetcher even where the preset enables it")
    ap.add_argument("--concurrency", type=int, default=0,
                    help="traffic mode: serve through N continuous-batching slots (0 = one-shot)")
    ap.add_argument("--requests", type=int, default=8,
                    help="traffic mode: number of requests to submit")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="traffic mode: open-loop Poisson arrivals, req/s (0 = all at once)")
    ap.add_argument("--profile-out", default="",
                    help="write this run's demand-access trace (AccessTrace JSON) "
                         "here at exit; profile with --no-prefetch so the trace "
                         "sees every fault (DESIGN.md §11; after2 only)")
    ap.add_argument("--retier-from", default="",
                    help="re-tier the artifact from a prior --profile-out trace "
                         "before cold start (promote demand-faulted units, demote "
                         "untouched residents) and drive the predictive "
                         "prefetcher from its transition table (after2 only)")
    ap.add_argument("--retier-online", action="store_true",
                    help="attach the online re-tiering daemon (DESIGN.md §12): "
                         "watch the live access trace and adapt the hot set in "
                         "place — promote = prefetch preload, demote = eviction "
                         "— with ZERO restarts (after2 only)")
    ap.add_argument("--retier-interval", type=int, default=16,
                    help="online re-tier cadence in serving steps (default 16)")
    ap.add_argument("--retier-decay", type=float, default=0.5,
                    help="per-tick decay of the merged trace history in [0, 1]: "
                         "1 = lifetime counts, 0 = newest window only")
    ap.add_argument("--retier-compact-every", type=int, default=0,
                    help="online mode: rewrite the artifact (out-of-place, "
                         "rename-committed) every N plan applications so the "
                         "NEXT cold start boots the adapted hot set (0 = never)")
    ap.add_argument("--mesh", default="",
                    help="shard serving over a DATAxMODEL debug mesh (e.g. 2x4): "
                         "tier-0 load and tier-1 faults device_put shards, the "
                         "residency budget charges per-device bytes (DESIGN.md "
                         "§15.1; needs that many devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--admission", default="fifo", choices=["fifo", "slo"],
                    help="scheduler admission policy (DESIGN.md §15.2): fifo = "
                         "strict arrival order (default), slo = deadline-aware "
                         "shed/re-order (traffic mode)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="SLO admission: per-request latency deadline in ms "
                         "(0 = none; requests projected to miss it are shed)")
    ap.add_argument("--snapshot-out", default="",
                    help="write the warmed server's snapshot (residency set + "
                         "LRU order + predictor + artifact identity, DESIGN.md "
                         "§15.3) here at exit (after2 only)")
    ap.add_argument("--restore-from", default="",
                    help="restore a --snapshot-out document before admitting "
                         "traffic: the replica cold-starts RESIDENT-warm "
                         "instead of re-faulting its hot set (after2 only)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="serve through N in-process replicas federated by a "
                         "FleetController (DESIGN.md §14): each replica runs "
                         "the one-shot workload, the controller syncs traces "
                         "and pushes the learned hot set to all of them "
                         "(implies --retier-online; after2 one-shot only)")
    args = ap.parse_args(argv)
    if (args.profile_out or args.retier_from or args.retier_online) and args.mode != "after2":
        ap.error("--profile-out/--retier-from/--retier-online need the "
                 "two-tier runtime (--mode after2)")
    if args.host_budget_bytes and args.mode != "after2":
        ap.error("--host-budget-bytes governs the tier-1 residency layer "
                 "(--mode after2 only)")
    if (args.snapshot_out or args.restore_from) and args.mode != "after2":
        ap.error("--snapshot-out/--restore-from serialize the tier-1 "
                 "residency set (--mode after2 only)")
    if args.admission == "fifo" and args.deadline_ms:
        ap.error("--deadline-ms needs --admission slo (FIFO never sheds)")
    if args.deadline_ms < 0:
        ap.error("--deadline-ms must be >= 0")
    mesh = None
    if args.mesh:
        try:
            data_ax, model_ax = (int(x) for x in args.mesh.lower().split("x"))
        except ValueError:
            ap.error(f"--mesh wants DATAxMODEL (e.g. 2x4), got {args.mesh!r}")
        try:
            mesh = make_debug_mesh(data_ax, model_ax)
        except ValueError as e:  # not enough devices: surface the XLA_FLAGS hint
            ap.error(str(e))
    if args.host_budget_bytes < 0:
        ap.error("--host-budget-bytes must be >= 0")
    if not 0.0 <= args.retier_decay <= 1.0:
        ap.error("--retier-decay must be in [0, 1]")
    if args.retier_interval < 1:
        # fail as a usage error here, not as a traceback after the whole
        # cold start has already run (RetierDaemon validates too, but by
        # then the tier-0 read + hot-set preload were paid for)
        ap.error("--retier-interval must be >= 1")
    if args.fleet:
        if args.fleet < 2:
            ap.error("--fleet needs at least 2 replicas to federate")
        if args.mode != "after2":
            ap.error("--fleet needs the two-tier runtime (--mode after2)")
        if args.concurrency > 0:
            ap.error("--fleet drives the one-shot path; drop --concurrency")
        if args.host_budget_bytes or args.profile_out or args.retier_from:
            ap.error("--fleet composes with none of --host-budget-bytes/"
                     "--profile-out/--retier-from (yet)")
        args.retier_online = True  # the fleet federates RetierDaemons
    if args.retier_from and (args.no_prefetch or args.policy == "strict"):
        # without a prefetcher (explicit --no-prefetch, or the strict
        # preset's prefetch-off default) the trained predictor would be
        # silently dropped — the opposite of what the flag promises
        ap.error("--retier-from drives the predictive prefetcher; drop "
                 "--no-prefetch / use --policy stats|full (profiling runs "
                 "want --no-prefetch, re-serve runs don't)")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(collect_moe_usage=cfg.moe is not None)
    model = build_model(cfg)
    outdir = os.path.join(args.artifact_dir, cfg.name)

    if args.policy == "strict":
        profile = DeploymentProfile(resident_experts=0, hot_vocab_fraction=0.0,
                                    min_tier1_bytes=1 << 14, vocab_row_group=max(64, cfg.vocab_size // 16))
        stats = None
    elif args.policy == "full":
        profile = DeploymentProfile(resident_experts=-1, hot_vocab_fraction=1.0)
        stats = None
    else:  # stats
        profile = DeploymentProfile(
            resident_experts=args.resident_experts,
            hot_vocab_fraction=args.hot_vocab,
            min_tier1_bytes=1 << 14,
            vocab_row_group=max(64, cfg.vocab_size // 16),
        )
        pipe = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 128, 8))
        stats = pipe.vocab_row_stats(row_group=profile.vocab_row_group)

    print(f"[serve] analyzing {cfg.name} under profile {profile.name}/{args.policy}")
    result = analyze(model, profile, hot_units_stats=stats, trace_B=1, trace_S=32)
    print("[serve] plan:", json.dumps(result.summary(), default=str)[:400])

    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    os.makedirs(outdir, exist_ok=True)
    # crash recovery before any writer exists: a prior run killed mid-way
    # through an artifact rewrite (retier compaction, checkpoint save)
    # leaves *.partial staging dirs behind — never committed, safe to drop
    removed = clean_partials(outdir)
    if removed:
        print(f"[serve] removed {len(removed)} orphaned partial(s): "
              + ", ".join(os.path.basename(p) for p in removed))
    if args.mode in ("before", "after1"):
        write_monolithic({"params": params, "opt_state": {"m": opt.m, "v": opt.v}},
                         outdir, pruned=args.mode == "after1")
    else:
        build_artifact(params, result, outdir)

    predictor = None
    if args.retier_from:
        # one profile→re-tier cycle (DESIGN.md §11): replan from the trace,
        # rewrite the artifact out-of-place, serve from the re-tiered copy
        # with the trace-trained predictor armed
        prof_trace = AccessTrace.load(args.retier_from)
        result.plan, rep = replan_from_trace(result.plan, prof_trace, result.reach)
        retier_dir = outdir.rstrip("/") + "-retier"
        retier_artifact(outdir, result.plan, out_dir=retier_dir, report=rep)
        outdir = retier_dir
        predictor = TransitionPredictor.from_trace(prof_trace)
        print(f"[serve] re-tiered from {args.retier_from} -> {retier_dir}:",
              json.dumps(rep.summary()))

    if args.fleet:
        return _serve_fleet(model, result, outdir, args, cfg)

    warm_B = 1 if args.concurrency > 0 else args.batch
    # the context manager guarantees prefetcher/store teardown even when
    # the request path raises (a leaked reader/uploader thread would hang
    # the process on exit)
    failed = 0
    arbiter = HostArbiter(args.host_budget_bytes) if args.host_budget_bytes else None
    admission = None
    if args.admission == "slo":
        admission = SLOAdmission(
            default_deadline_s=(args.deadline_ms / 1e3) if args.deadline_ms else None
        )
    with cold_start(model, outdir, result if args.mode == "after2" else None,
                    mode=args.mode, warm_shapes=((warm_B, args.prompt_len),),
                    residency=args.policy if args.mode == "after2" else None,
                    device_budget_bytes=args.device_budget_bytes or None,
                    host_arbiter=arbiter,
                    prefetch=False if args.no_prefetch else None,
                    trace=bool(args.profile_out), predictor=predictor,
                    retier_online=args.retier_online,
                    retier_interval=args.retier_interval,
                    retier_decay=args.retier_decay,
                    retier_compact_every=args.retier_compact_every,
                    mesh=mesh, admission=admission,
                    restore_from=args.restore_from or None) as server:
        print(f"[serve] cold start ({args.mode}):", json.dumps(server.report.to_dict(), default=float))
        if server.restore_report is not None:
            rr = server.restore_report
            print(f"[serve] warm restore: {rr['restored']}/{rr['requested']} units "
                  f"resident ({rr['moved_bytes']:,}B replayed, "
                  f"predictor {'armed' if rr['predictor_armed'] else 'absent'})")

        engine = GenerationEngine(server, max_seq=args.prompt_len + args.gen_steps + 8)
        if args.concurrency > 0:
            failed = _serve_traffic(engine, args, cfg)
        else:
            prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)
            out, stats_r = engine.generate(prompts, args.gen_steps)
            print(f"[serve] generated {out.shape}; prefill={stats_r.prefill_s*1e3:.1f}ms "
                  f"decode={stats_r.decode_s*1e3:.1f}ms faults={stats_r.faulted_units} "
                  f"({stats_r.faulted_bytes/2**20:.1f}MiB, {stats_r.fault_s*1e3:.1f}ms)")
        if server.tiered is not None:
            ts = server.tiered.stats
            budget = server.tiered.residency.budget_bytes
            print(f"[serve] resident fraction: {server.tiered.resident_fraction():.3f}; "
                  f"resident {server.tiered.resident_bytes:,}B"
                  + (f" / budget {budget:,}B" if budget else " (no budget)"))
            print(f"[serve] prefetch hit rate {ts.prefetch_hit_rate:.2f}; "
                  f"evictions {ts.evictions}; refaults {ts.refaults}; "
                  f"stall p99 {ts.stall_percentile(99)*1e3:.2f}ms")
            if server.prefetcher is not None and server.prefetcher.predictor is not None:
                ps = server.prefetcher.stats
                print(f"[serve] predictor: observed {ps.observed} keys, "
                      f"predicted {ps.predicted} ahead-of-schedule loads")
        if arbiter is not None:
            audit = arbiter.audit()
            hs = arbiter.stats
            print(f"[serve] host arbiter: {audit['resident_bytes']:,}B resident "
                  f"/ {audit['budget_bytes']:,}B host budget "
                  f"({audit['pinned_bytes']:,}B pinned); "
                  f"{hs.evictions} evictions ({hs.evicted_bytes:,}B), "
                  f"{hs.overshoots} overshoots, "
                  f"{hs.headroom_denials} prefetch headroom denials")
        if server.retier_daemon is not None:
            _print_daemon_stats(server)
        if args.profile_out and server.tiered is not None and server.tiered.trace is not None:
            # with the daemon on, the live trace is only the newest window —
            # save the decayed merge of everything the run observed instead
            t = (server.retier_daemon.trace_snapshot()
                 if server.retier_daemon is not None else server.tiered.trace)
            t.save(args.profile_out)
            print(f"[serve] wrote access trace to {args.profile_out} "
                  f"({t.batches} batches, {len(t.faults)} faulted units, "
                  f"{len(t.transitions)} transition sources)")
        if args.snapshot_out and server.tiered is not None:
            snap = server.snapshot()
            server_snapshot.save(snap, args.snapshot_out)
            print(f"[serve] wrote server snapshot to {args.snapshot_out} "
                  f"({len(snap['resident'])} resident units, "
                  f"predictor {'included' if snap['predictor'] else 'absent'})")
    if failed:
        print(f"[serve] FAILED: {failed} request(s) failed or never finished")
    return 1 if failed else 0


def _print_daemon_stats(server, label: str = "online retier") -> None:
    """One line of daemon accounting + the predictor counters the daemon's
    refresh cycle feeds (hit rate / observed / predicted)."""
    ds = server.retier_daemon.stats
    pred = ""
    if server.tiered is not None and server.prefetcher is not None:
        ts, ps = server.tiered.stats, server.prefetcher.stats
        pred = (f", predictor hit rate {ts.prefetch_hit_rate:.2f} "
                f"({ps.observed} observed, {ps.predicted} predicted)")
    print(f"[serve] {label}: {ds.ticks} ticks, {ds.applies} applies "
          f"(+{ds.promoted_units}/-{ds.demoted_units} units, "
          f"{ds.evicted_bytes:,}B evicted, "
          f"{ds.predictor_refreshes} predictor refreshes, "
          f"{ds.compactions} compactions{pred}); zero restarts")


def _serve_fleet(model, result, outdir, args, cfg) -> int:
    """``--fleet N``: the one-shot workload through N in-process replicas
    federated by one FleetController (DESIGN.md §14). Each replica cold-
    starts with its own daemon registered to the fleet, serves the batch,
    and the controller syncs after every replica — so by the time replica
    k serves, it already carries the hot set replicas 0..k-1 learned."""
    fleet = FleetController(decay=args.retier_decay)
    servers = []
    failed = 0
    try:
        for i in range(args.fleet):
            s = cold_start(
                model, outdir, result, mode="after2",
                warm_shapes=((args.batch, args.prompt_len),),
                residency=args.policy,
                device_budget_bytes=args.device_budget_bytes or None,
                prefetch=False if args.no_prefetch else None,
                retier_online=True,
                retier_interval=args.retier_interval,
                retier_decay=args.retier_decay,
                retier_compact_every=args.retier_compact_every,
                fleet=fleet, replica_name=f"replica-{i}",
            )
            servers.append(s)
            print(f"[serve] replica-{i} cold start:",
                  json.dumps(s.report.to_dict(), default=float))
        for i, s in enumerate(servers):
            engine = GenerationEngine(s, max_seq=args.prompt_len + args.gen_steps + 8)
            prompts = jax.random.randint(
                jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)
            out, st = engine.generate(prompts, args.gen_steps)
            if out.shape[0] != args.batch:
                failed += 1
            print(f"[serve] replica-{i}: generated {out.shape}; "
                  f"faults={st.faulted_units} ({st.faulted_bytes/2**20:.2f}MiB, "
                  f"{st.fault_s*1e3:.1f}ms)")
            rep = fleet.sync()
            print(f"[serve] fleet sync: {rep['windows']}/{rep['pulled']} windows, "
                  f"pushed to {len(rep['pushed'])} replicas "
                  f"(+{rep['promoted']}/-{rep['demoted']} units)"
                  + (f", FAILED {sorted(rep['failed'])}" if rep["failed"] else ""))
        for i, s in enumerate(servers):
            _print_daemon_stats(s, label=f"replica-{i} retier")
        fs = fleet.stats
        print(f"[serve] fleet: {fs.syncs} syncs, {fs.replans} replans, "
              f"{fs.pushes} pushes ({fs.push_failures} failed), "
              f"{fs.bootstraps} warm bootstraps")
    finally:
        for s in servers:
            s.close()
    if failed:
        print(f"[serve] FAILED: {failed} replica run(s) produced short output")
    return 1 if failed else 0


def _serve_traffic(engine: GenerationEngine, args, cfg) -> int:
    """Open-loop traffic through the continuous-batching scheduler.
    Returns the number of failed/unfinished requests so the launcher can
    exit nonzero (CI smoke must catch silent request failures)."""
    sched = ContinuousBatchingScheduler(engine, max_batch=args.concurrency)
    sched.warm_compile()  # first step should serve, not compile
    rng = np.random.default_rng(0)
    prompts = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (args.prompt_len,), 0, cfg.vocab_size))
        for i in range(args.requests)
    ]
    deadline_s = (args.deadline_ms / 1e3) if args.deadline_ms else None
    stop = threading.Event()
    loop = threading.Thread(target=sched.serve_forever, args=(stop,), name="sched-loop")
    loop.start()
    t0 = time.perf_counter()
    reqs = []
    try:
        for p in prompts:
            reqs.append(sched.queue.submit(p, args.gen_steps, deadline_s=deadline_s))
            if args.arrival_rate > 0:
                time.sleep(rng.exponential(1.0 / args.arrival_rate))
        # bail out early if the loop thread dies instead of blocking the
        # full timeout per request
        deadline = time.perf_counter() + 600.0
        pending = list(reqs)
        while pending and loop.is_alive() and time.perf_counter() < deadline:
            if pending[0].wait(1.0):
                pending.pop(0)
        pending = [r for r in pending if not r.done]
        if pending:
            print(f"[serve] WARNING: {len(pending)}/{len(reqs)} requests unfinished "
                  f"(loop alive={loop.is_alive()})")
    finally:
        stop.set()
        loop.join()
    wall = time.perf_counter() - t0
    done = [r for r in reqs if r.done and r.error is None]
    shed = [r for r in reqs if r.shed]
    lat = np.array([r.latency_s for r in done]) if done else np.zeros(1)
    ttft = np.array([r.ttft_s for r in done]) if done else np.zeros(1)
    print(f"[serve] traffic: {len(done)}/{len(reqs)} ok in {wall:.2f}s "
          f"({len(done) / wall:.2f} req/s over {sched.stats.steps} batched steps, "
          f"max_active={sched.stats.max_active}"
          + (f", shed={len(shed)}" if shed else "") + ")")
    print(f"[serve] latency p50={np.percentile(lat, 50) * 1e3:.0f}ms "
          f"p99={np.percentile(lat, 99) * 1e3:.0f}ms; "
          f"ttft p50={np.percentile(ttft, 50) * 1e3:.0f}ms; "
          f"step faults={sched.stats.faulted_units} ({sched.stats.fault_s * 1e3:.1f}ms)")
    for r in reqs:
        if r.error and not r.shed:
            print(f"[serve] request {r.rid} failed: {r.error}")
    # an SLO shed is the policy doing its job — a deliberate drop, not a
    # serving failure; rejects/exceptions/unfinished still exit nonzero
    return sum(1 for r in reqs if (r.error is not None and not r.shed) or not r.done)


if __name__ == "__main__":
    raise SystemExit(main())
