import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the full-size model's step function is jitted with NamedSharding in/out
specs on the production mesh, ``.lower().compile()`` must succeed, and the
compiled artifact yields

  * memory_analysis()  — bytes per device (fits/doesn't fit),
  * cost_analysis()    — HLO FLOPs + bytes accessed,
  * the optimized HLO  — collective-op byte accounting (repro.utils.hlo),

which benchmarks/roofline.py turns into the three-term roofline table.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multi-pod] [--out results/]

NOTE kernels: cells lower with use_pallas=False so cost_analysis sees real
FLOPs (a Pallas custom-call is opaque to the XLA cost model); the Pallas
kernels target real-TPU execution and are validated separately.
"""

import argparse
import gc
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh, mesh_label
from repro.models.zoo import build_model
from repro.optim import abstract_adamw
from repro.sharding import param_shardings, resolve_pspec, use_mesh
from repro.sharding.rules import ACT_RULES
from repro.utils import hlo as hlo_util
from repro.utils.tree import flatten_with_paths, tree_from_flat

DEFAULT_OUT = "benchmarks/results/dryrun"


def _batch_shardings(batch_axes: dict, batch_specs: dict, mesh) -> dict:
    out = {}
    for k, spec in batch_specs.items():
        axes = batch_axes[k]
        out[k] = NamedSharding(mesh, resolve_pspec(axes, spec.shape, mesh, ACT_RULES))
    return out


def _tree_shardings(axes_tree, spec_tree, mesh):
    from repro.utils.tree import flatten_axes_tree

    flat_axes = dict(flatten_axes_tree(axes_tree))
    out = {}
    for path, leaf in flatten_with_paths(spec_tree):
        axes = flat_axes[path]
        out[path] = NamedSharding(mesh, resolve_pspec(axes, leaf.shape, mesh, ACT_RULES))
    return tree_from_flat(out)


def build_cell(arch: str, shape_name: str, mesh, *, logits_chunk: int = 512,
               remat: str = "full", fsdp: bool = True, micro_batches: int = 0,
               extra_cfg: dict | None = None):
    """Construct (fn, abstract args, in_shardings, out_shardings) for a cell.

    ``micro_batches`` — gradient-accumulation factor for the train step
    (0 = auto: scale with model size so activation memory fits HBM; the
    global batch is unchanged, activations shrink by the factor).
    """
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    overrides = dict(use_pallas=False, fsdp=fsdp, remat=remat)
    if shape.kind == "train" and cfg.vocab_size >= 64_000 and logits_chunk:
        overrides["logits_chunk"] = logits_chunk
    if extra_cfg:
        overrides.update(extra_cfg)
        micro_batches = int(overrides.pop("micro_batches", micro_batches))
    cfg = cfg.replace(**overrides)
    model = build_model(cfg)
    if micro_batches == 0:
        # auto: deeper accumulation for bigger models (activation memory
        # scales 1/micro at constant global batch); data axis is 16 so the
        # per-microbatch batch stays ≥ 1 per data shard at micro ≤ 16
        n = model.num_params()
        micro_batches = 16 if n > 40e9 else (8 if n > 8e9 else 4)
        # per-microbatch batch must still cover every batch shard: on the
        # multi-pod mesh (pod×data = 32) micro=16 would leave 8 rows for 32
        # shards -> replication (observed: multi-pod train cells lost their
        # 2x state-halving win). Clamp.
        batch_shards = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                batch_shards *= mesh.shape[ax]
        if shape.kind == "train":
            micro_batches = max(1, min(micro_batches, shape.global_batch // batch_shards))
    if cfg.layers_per_unit == 1 and "layers_per_unit" not in (extra_cfg or {}):
        # auto: group deep uniform stacks 4 layers per scanned unit
        if cfg.num_layers >= 40 and cfg.recurrent is None and cfg.xlstm is None \
                and cfg.local_global_pattern is None and cfg.vlm is None:
            for k in (4, 2):
                lead = cfg.moe.first_dense_layers if cfg.moe else 0
                if (cfg.num_layers - lead) % k == 0:
                    cfg = cfg.replace(layers_per_unit=k)
                    model = build_model(cfg)
                    break

    log_axes = model.logical_axes()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        # fp32 MASTER weights: the optimizer owns fp32 params; the model
        # computes on a bf16 cast taken once per step. Without this, the
        # fp32 copies AdamW takes of bf16 params make XLA keep (and
        # all-gather!) the weights in fp32 inside the training loop —
        # doubling FSDP gather volume (observed; EXPERIMENTS.md §Perf).
        abstract = model.abstract(dtype=jnp.float32)
        p_sh = param_shardings(log_axes, abstract, mesh, fsdp=cfg.fsdp)
        batch_specs, batch_axes = model.train_batch_spec(B, S, multimodal=True)
        b_sh = _batch_shardings(batch_axes, batch_specs, mesh)
        opt_abs = abstract_adamw(abstract)
        # moments shard exactly like their parameters; step replicates
        opt_sh = type(opt_abs)(
            step=NamedSharding(mesh, PartitionSpec()), m=p_sh, v=p_sh
        )
        from repro.optim import AdamWConfig, adamw_update

        acfg = AdamWConfig()
        n_micro = micro_batches if shape.global_batch % max(micro_batches, 1) == 0 else 1
        flat_psh = dict(flatten_with_paths(p_sh))

        def _constrain_like_params(tree):
            flat = flatten_with_paths(tree)
            out = {
                p: jax.lax.with_sharding_constraint(v, flat_psh[p]) for p, v in flat
            }
            return tree_from_flat(out)

        def train_step(params, opt_state, batch):
            pb = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
            if n_micro == 1:
                loss, grads = jax.value_and_grad(model.loss_fn)(pb, batch)
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            else:
                # gradient accumulation: global batch constant, activation
                # memory / n_micro. The fp32 accumulator is pinned to the
                # param shardings so each microbatch's grads reduce-scatter
                # instead of all-reducing replicated fp32 copies.
                def micro(acc, mb):
                    l, g = jax.value_and_grad(model.loss_fn)(pb, mb)
                    al, ag = acc
                    ag = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), ag, g)
                    ag = _constrain_like_params(ag)
                    return (al + l, ag), None

                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                mbs = jax.tree.map(
                    lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), batch
                )
                (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros), mbs)
                loss = loss / n_micro
                grads = jax.tree.map(lambda g: g / n_micro, grads)
            params, opt_state = adamw_update(acfg, grads, opt_state, params)
            return params, opt_state, loss

        args = (abstract, opt_abs, batch_specs)
        in_sh = (p_sh, opt_sh, b_sh)
        out_sh = (p_sh, opt_sh, NamedSharding(mesh, PartitionSpec()))
        fn = train_step
    elif shape.kind == "prefill":
        abstract = model.abstract(dtype=jnp.bfloat16)
        p_sh = param_shardings(log_axes, abstract, mesh, fsdp=cfg.fsdp)
        batch_specs, batch_axes = model.prefill_batch_spec(B, S, multimodal=True)
        b_sh = _batch_shardings(batch_axes, batch_specs, mesh)
        cache_axes = model.cache_axes(B, S, multimodal=True)
        cache_sh = _tree_shardings(cache_axes, model.abstract_cache(B, S, multimodal=True), mesh)
        logits_sh = NamedSharding(
            mesh, resolve_pspec(("batch", "vocab"), (B, cfg.vocab_size), mesh, ACT_RULES)
        )
        fn = model.prefill
        args = (abstract, batch_specs)
        in_sh = (p_sh, b_sh)
        out_sh = (logits_sh, cache_sh)
    else:  # decode
        abstract = model.abstract(dtype=jnp.bfloat16)
        p_sh = param_shardings(log_axes, abstract, mesh, fsdp=cfg.fsdp)
        cache_abs = model.abstract_cache(B, S, multimodal=True)
        cache_axes = model.cache_axes(B, S, multimodal=True)
        cache_sh = _tree_shardings(cache_axes, cache_abs, mesh)
        batch_specs, batch_axes = model.decode_batch_spec(B)
        b_sh = _batch_shardings(batch_axes, batch_specs, mesh)
        logits_sh = NamedSharding(
            mesh, resolve_pspec(("batch", "vocab"), (B, cfg.vocab_size), mesh, ACT_RULES)
        )
        fn = model.decode_step
        args = (abstract, cache_abs, batch_specs)
        in_sh = (p_sh, cache_sh, b_sh)
        out_sh = (logits_sh, cache_sh)
    return model, fn, args, in_sh, out_sh


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = DEFAULT_OUT, verbose: bool = True,
             extra_cfg: dict | None = None, tag: str = "",
             kernelized: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_label(mesh),
               "status": "skipped", "reason": reason}
        _save(rec, out_dir, tag)
        return rec

    t0 = time.time()
    model, fn, args, in_sh, out_sh = build_cell(arch, shape_name, mesh, extra_cfg=extra_cfg)
    # donate params+opt (train) / caches (decode) — the production step
    # aliases them, halving resident state at peak
    donate = (0, 1) if shape.kind == "train" else ((1,) if shape.kind == "decode" else ())
    with use_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = hlo_util.extract_memory(compiled)
    raw_flops, raw_bytes = hlo_util.extract_cost(compiled)
    hlo_text = compiled.as_text()
    # loop-aware accounting: the partitioned module is the PER-DEVICE
    # program; ×chips gives the global step cost. (cost_analysis counts
    # while bodies once — wrong for scanned layers/microbatches; see
    # utils.hlocost.)
    from repro.utils import hlocost

    cost = hlocost.analyze(hlo_text, kernelized=kernelized)

    n_chips = int(np.prod(mesh.devices.shape))
    model_flops = _model_flops(model, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_label(mesh),
        "status": "ok",
        "num_chips": n_chips,
        "hlo_flops": cost.flops * n_chips,
        "hlo_dot_flops": cost.dot_flops * n_chips,
        "hlo_bytes": cost.bytes * n_chips,
        "collective_bytes": cost.collective_bytes,  # per device
        "collectives": {"bytes": cost.collective_by_kind, "count": cost.collective_count},
        "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
        "memory": mem,
        "model_flops": model_flops,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "params": model.num_params(),
        "active_params": model.active_params(),
        "tag": tag,
    }
    if verbose:
        per_dev = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        print(
            f"[dryrun] {arch} × {shape_name} × {mesh_label(mesh)}: OK "
            f"flops/dev={cost.flops:.3e} bytes/dev={cost.bytes:.3e} "
            f"coll/dev={cost.collective_bytes:.3e} "
            f"mem/dev={per_dev/2**30:.2f}GiB lower={t_lower:.0f}s compile={t_compile:.0f}s"
        )
        print("  memory_analysis:", {k: f"{v/2**30:.3f}GiB" for k, v in mem.items() if "size" in k})
        print("  collectives:", {k: f"{v:.2e}B" for k, v in cost.collective_by_kind.items()})
    _save(rec, out_dir, tag)
    del compiled, lowered, jitted
    gc.collect()
    return rec


def _model_flops(model, shape) -> float:
    """6·N_active·D for train, 2·N_active·D for inference steps."""
    n = model.active_params()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * shape.tokens


def _save(rec: dict, out_dir: str, tag: str = "") -> None:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=list(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--tag", default="", help="suffix for perf-iteration variants")
    ap.add_argument("--kernelized", action="store_true",
                    help="byte model with attention scores VMEM-resident (Pallas kernels)")
    ap.add_argument("--override", default="", help="k=v,k=v config overrides")
    args = ap.parse_args(argv)

    from repro.configs import ARCH_IDS

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    extra = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        extra[k] = (
            int(v) if v.lstrip("-").isdigit() else
            (v == "True") if v in ("True", "False") else v
        )

    failures = 0
    for arch in archs:
        for shape in shapes:
            try:
                run_cell(arch, shape, multi_pod=args.multi_pod, out_dir=args.out,
                         extra_cfg=extra or None, tag=args.tag,
                         kernelized=args.kernelized)
            except Exception:
                failures += 1
                print(f"[dryrun] {arch} × {shape}: FAILED", file=sys.stderr)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
