"""HLO text analysis: collective-byte accounting + roofline terms.

The dry-run (launch/dryrun.py) lowers and compiles every
(arch × shape × mesh) cell. ``compiled.cost_analysis()`` exposes FLOPs and
bytes-accessed, but *not* collective traffic — we recover that by parsing the
optimized HLO text and summing operand sizes of every collective op
(§ROOFLINE ANALYSIS in the assignment).

Hardware model (TPU v5e, per assignment):
  peak bf16 compute : 197 TFLOP/s / chip
  HBM bandwidth     : 819 GB/s / chip
  ICI link bandwidth: ~50 GB/s / link
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field, asdict

import numpy as np

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "s32": 4,
    "u32": 4,
    "s64": 8,
    "u64": 8,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "bf16": 2,
    "f16": 2,
    "f32": 4,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

# e.g.  bf16[256,4096,512]{2,1,0}   or  f32[]   or  (f32[8], u32[8])
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' occurrence."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    """Per-collective-kind byte totals for one HLO module (output-shape bytes,
    the standard proxy for traffic volume per participant)."""

    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective instruction in HLO text.

    We parse instruction lines of the form
      ``%name = <shape(s)> <opcode>(...)``
    and attribute the *result* bytes to the opcode. ``-start`` variants are
    counted; their ``-done`` halves are skipped to avoid double counting.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3 :]
        # rhs starts with the result shape, then the opcode.
        m = re.match(r"(\(?[a-z0-9]+\[[0-9,]*\][^ ]*\)?(?:, [^ ]+)*)\s+([a-z0-9-]+)", rhs)
        if not m:
            continue
        shapes_str, opcode = m.groups()
        kind = None
        for c in _COLLECTIVE_OPS:
            if opcode == c or opcode == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        nbytes = sum(_shape_bytes(x) for x in _SHAPE_RE.findall(shapes_str) for x in [f"{x[0]}[{x[1]}]"])
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    """Three-term roofline for one compiled (arch, shape, mesh) cell.

    All terms are *seconds for the whole step on the whole mesh*, i.e. the
    per-chip serial time assuming perfect overlap within each term.
    """

    arch: str
    shape: str
    mesh: str
    num_chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    bytes_per_device: float = 0.0
    collective_detail: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.num_chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.num_chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # collective_bytes is already per-participant volume (result bytes);
        # each chip moves its share over its ICI links.
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / max term — 1.0 means compute-bound at peak."""
        b = self.bound_s
        return self.compute_s / b if b else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def extract_cost(compiled) -> tuple[float, float]:
    """(flops, bytes_accessed) from compiled.cost_analysis(), robust to the
    dict/list-of-dicts signature differences across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, nbytes


def extract_memory(compiled) -> dict:
    """Bytes-per-device figures from compiled.memory_analysis()."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def dense_model_flops(num_params: int, tokens: int) -> float:
    """6·N·D rule of thumb for a train step; callers pass active params for
    MoE and divide by 3 for inference (2·N·D)."""
    return 6.0 * num_params * tokens
