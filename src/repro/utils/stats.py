"""Statistics used by the paper's evaluation (§5.1): Mann-Whitney U test and
Cohen's d effect size. Implemented from scratch (no scipy in the container).

The paper runs each app 20 times, tests *after2* vs *before* with
Mann-Whitney U (p < 0.05) and reports Cohen's d (0.2 small / 0.5 medium /
0.8 large).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average ranks (1-based) with ties, like scipy.stats.rankdata."""
    sorter = np.argsort(x, kind="mergesort")
    inv = np.empty_like(sorter)
    inv[sorter] = np.arange(len(x))
    xs = x[sorter]
    # tie groups
    obs = np.r_[True, xs[1:] != xs[:-1]]
    dense = obs.cumsum()[inv]
    # cumulative counts per group
    counts = np.r_[np.nonzero(obs)[0], len(obs)]
    return 0.5 * (counts[dense] + counts[dense - 1] + 1)


def mann_whitney_u(a, b) -> tuple[float, float]:
    """Two-sided Mann-Whitney U test with normal approximation + tie
    correction. Returns ``(U, p_value)``.

    Suitable for the paper's n=20 samples; the normal approximation is the
    standard choice for n1, n2 >= 8.
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n1, n2 = len(a), len(b)
    if n1 == 0 or n2 == 0:
        raise ValueError("empty sample")
    ranks = _rankdata(np.concatenate([a, b]))
    r1 = ranks[:n1].sum()
    u1 = r1 - n1 * (n1 + 1) / 2.0
    u2 = n1 * n2 - u1
    u = min(u1, u2)
    mu = n1 * n2 / 2.0
    # tie correction for variance
    n = n1 + n2
    _, counts = np.unique(np.concatenate([a, b]), return_counts=True)
    tie_term = ((counts**3 - counts).sum()) / (n * (n - 1)) if n > 1 else 0.0
    sigma2 = n1 * n2 / 12.0 * ((n + 1) - tie_term)
    if sigma2 <= 0:
        return u, 1.0
    z = (u - mu + 0.5) / math.sqrt(sigma2)  # continuity correction
    p = 2.0 * 0.5 * math.erfc(abs(z) / math.sqrt(2.0))
    return u, min(1.0, p)


def cohens_d(a, b) -> float:
    """Cohen's d with pooled standard deviation (paper §5.1)."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n1, n2 = len(a), len(b)
    va, vb = a.var(ddof=1), b.var(ddof=1)
    pooled = ((n1 - 1) * va + (n2 - 1) * vb) / max(n1 + n2 - 2, 1)
    if pooled == 0:
        return 0.0 if a.mean() == b.mean() else float("inf")
    return abs(a.mean() - b.mean()) / math.sqrt(pooled)


@dataclass
class Comparison:
    """before-vs-after comparison in the paper's reporting format."""

    name: str
    before_mean: float
    after_mean: float
    reduction_pct: float
    u_stat: float
    p_value: float
    effect_size: float

    @property
    def significant(self) -> bool:
        return self.p_value < 0.05

    @property
    def effect_label(self) -> str:
        d = self.effect_size
        if d >= 0.8:
            return "large"
        if d >= 0.5:
            return "medium"
        if d >= 0.2:
            return "small"
        return "negligible"


def compare(name: str, before, after) -> Comparison:
    before = np.asarray(before, dtype=np.float64)
    after = np.asarray(after, dtype=np.float64)
    u, p = mann_whitney_u(before, after)
    d = cohens_d(before, after)
    bm, am = float(before.mean()), float(after.mean())
    red = 100.0 * (bm - am) / bm if bm else 0.0
    return Comparison(name, bm, am, red, u, p, d)
