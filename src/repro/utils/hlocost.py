"""Loop-aware HLO cost analysis (FLOPs / bytes / collectives).

``compiled.cost_analysis()`` counts every while-loop *body once* — for a
scan-over-layers program that under-reports FLOPs by the layer count, and
for a gradient-accumulation scan by the microbatch count (verified
empirically; see EXPERIMENTS.md §Roofline methodology). This module parses
``compiled.as_text()`` and propagates *execution counts* through the
computation graph instead:

  * while-loop trip counts come from XLA's own loop analysis
    (``backend_config={"known_trip_count":{"n":…}}``),
  * fusions contribute their operand+result bytes (a fusion is one kernel:
    internals never touch HBM) and their internal dot FLOPs,
  * collective bytes are result-shape bytes × execution count, per kind.

FLOPs are exact for dot/convolution (2·M·N·K) and 1/element for marked
elementwise math; bytes are the fused top-level traffic model — both are
deliberately *structural* quantities, reproducible from the HLO alone.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# opcodes that move no HBM bytes of their own
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call", "opt-barrier",
}

# byte-counted opcodes (fusion-optimistic TPU model): ONLY ops that
# necessarily touch HBM on a TPU backend count traffic. XLA:CPU leaves
# converts/copies/transposes/elementwise unfused (inflating naive byte sums
# ~100×); on TPU those fuse into neighbouring kernels. Fusions count their
# operands+result (one kernel = one HBM round trip); standalone layout or
# elementwise ops are assumed fuseable and free.
_HBM_OPS = {
    "dot", "fusion", "convolution", "dynamic-slice", "dynamic-update-slice",
    "gather", "scatter", "reduce", "reduce-window", "sort", "rng",
    "rng-bit-generator", "concatenate", "pad",
}

_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "floor", "ceil", "sine", "cosine",
    "convert", "reduce",
}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    """Total bytes of all array shapes inside a (possibly tuple) type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b:
            total += _shape_elems(dims) * b
    return total


def _first_shape(type_str: str):
    m = _SHAPE_RE.match(type_str.lstrip("("))
    if not m:
        return None, ()
    dt, dims = m.groups()
    return dt, tuple(int(d) for d in dims.split(",") if d)


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operand_str: str
    attrs: str

    def operand_names(self) -> list:
        return re.findall(r"%([\w.\-]+)", self.operand_str)


_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def parse_hlo(text: str) -> tuple[dict, str]:
    """-> ({computation: [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            continue
        m = _INSTR_RE.match(line)
        if not m or cur is None:
            continue
        name, rhs = m.groups()
        op_m = _OPCODE_RE.search(rhs)
        if not op_m:
            continue
        opcode = op_m.group(1)
        type_str = rhs[: op_m.start()].strip()
        # balanced-paren operand region
        i = op_m.end()
        depth = 1
        j = i
        while j < len(rhs) and depth:
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
            j += 1
        comps[cur].append(
            Instr(name, type_str, opcode, rhs[i : j - 1], rhs[j:])
        )
    return comps, entry


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    collective_count: dict = field(default_factory=dict)
    dot_flops: float = 0.0

    def add_collective(self, kind: str, nbytes: float, count: float) -> None:
        self.collective_bytes += nbytes
        self.collective_by_kind[kind] = self.collective_by_kind.get(kind, 0.0) + nbytes
        self.collective_count[kind] = self.collective_count.get(kind, 0.0) + count


def _dot_flops(instr: Instr, types: dict) -> float:
    _, out_shape = _first_shape(instr.type_str)
    ops = instr.operand_names()
    if not ops:
        return 0.0
    lhs_type = types.get(ops[0], "")
    _, lhs_shape = _first_shape(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs + instr.operand_str)
    k = 1
    if m and lhs_shape:
        for d in m.group(1).split(","):
            if d:
                k *= lhs_shape[int(d)]
    out_elems = 1
    for d in out_shape:
        out_elems *= d
    return 2.0 * out_elems * k


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_read_bytes(comps: dict, types_by_comp: dict, comp: str) -> float:
    """HBM bytes a fused kernel reads: per fusion parameter, if every use
    inside the fusion is a slice/gather, only the sliced windows move (the
    loop-carried xs-slice pattern); otherwise the full parameter moves."""
    instrs = comps.get(comp, ())
    types = types_by_comp.get(comp, {})
    uses: dict[str, list] = {}
    params = []
    for i in instrs:
        if i.opcode == "parameter":
            params.append(i)
        for o in i.operand_names():
            uses.setdefault(o, []).append(i)
    total = 0.0
    for p in params:
        consumers = uses.get(p.name, [])
        if consumers and all(c.opcode in _SLICE_OPS for c in consumers):
            total += sum(_type_bytes(c.type_str) for c in consumers)
        else:
            total += _type_bytes(p.type_str)
    return total


# op_name markers of attention-score producers/consumers. Under the Pallas
# flash kernels (repro.kernels) these tensors are VMEM-resident: the
# "kernelized" byte model skips their HBM traffic, quantifying the kernels'
# effect on the memory roofline term (EXPERIMENTS.md §Perf). Conservative:
# the softmax elementwise chain between the two matmuls stays counted.
VMEM_SCORE_MARKERS = (
    "->bqkgs", "bqkgs,",  # flash attention QK^T / PV
    "->btsh", "btsh,",    # chunkwise mLSTM intra-chunk scores
    "->bkgs", "bkgs,",    # decode attention
    "->bhs", "bhs,",      # MLA decode scores
)


def analyze(text: str, *, kernelized: bool = False) -> HloCost:
    skip_markers = VMEM_SCORE_MARKERS if kernelized else ()
    comps, entry = parse_hlo(text)
    cost = HloCost()
    # result-type symbol table per computation
    types_by_comp = {
        c: {i.name: i.type_str for i in instrs} for c, instrs in comps.items()
    }

    def walk(comp: str, mult: float, bytes_on: bool) -> None:
        types = types_by_comp.get(comp, {})
        for instr in comps.get(comp, ()):  # noqa: B007
            op = instr.opcode
            if op == "while":
                m = _TRIP_RE.search(instr.attrs)
                trip = float(m.group(1)) if m else 1.0
                bm = re.search(r"body=%?([\w.\-]+)", instr.attrs)
                if bm:
                    walk(bm.group(1), mult * trip, bytes_on)
                continue
            if op == "conditional":
                for b in re.findall(r"(?:true_computation|false_computation|branch_computations=\{)[^,}]*%([\w.\-]+)", instr.attrs):
                    walk(b, mult, bytes_on)
                continue
            if op == "call":
                cm = re.search(r"to_apply=%?([\w.\-]+)", instr.attrs)
                if cm:
                    walk(cm.group(1), mult, bytes_on)
                continue
            if op == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
                skip = any(m in instr.attrs for m in skip_markers)
                if cm:
                    walk(cm.group(1), mult, False)  # flops only inside fusions
                    if bytes_on and not skip:
                        b = _type_bytes(instr.type_str) + _fusion_read_bytes(
                            comps, types_by_comp, cm.group(1)
                        )
                        cost.bytes += b * mult
                elif bytes_on and not skip:
                    b = _type_bytes(instr.type_str) + sum(
                        _type_bytes(types.get(o, "")) for o in instr.operand_names()
                    )
                    cost.bytes += b * mult
                continue

            kind = None
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                kind = base
            if kind is not None and not op.endswith("-done"):
                nb = _type_bytes(instr.type_str)
                cost.add_collective(kind, nb * mult, mult)
                if bytes_on:
                    cost.bytes += nb * mult
                continue

            if op == "dot":
                f = _dot_flops(instr, types) * mult
                cost.flops += f
                cost.dot_flops += f
                if bytes_on and not any(m in instr.attrs for m in skip_markers):
                    b = _type_bytes(instr.type_str) + sum(
                        _type_bytes(types.get(o, "")) for o in instr.operand_names()
                    )
                    cost.bytes += b * mult
                continue

            if op in _ELEMENTWISE_FLOPS:
                _, out_shape = _first_shape(instr.type_str)
                n = 1
                for d in out_shape:
                    n *= d
                cost.flops += n * mult

            if bytes_on and op in _HBM_OPS and not any(m in instr.attrs for m in skip_markers):
                ops_names = instr.operand_names()
                if op == "dynamic-slice" or op == "gather":
                    # reads only the sliced window, not the source buffer
                    b = 2 * _type_bytes(instr.type_str)
                elif op == "dynamic-update-slice":
                    # in-place: only the written window moves
                    upd = types.get(ops_names[1], "") if len(ops_names) > 1 else ""
                    b = 2 * _type_bytes(upd)
                elif op == "scatter":
                    upd = types.get(ops_names[-1], "") if ops_names else ""
                    b = 2 * _type_bytes(upd)
                else:
                    b = _type_bytes(instr.type_str) + sum(
                        _type_bytes(types.get(o, "")) for o in ops_names
                    )
                cost.bytes += b * mult

    if entry:
        walk(entry, 1.0, True)
    return cost
