"""Shared utilities: pytree path handling, statistics, HLO/roofline analysis."""

from repro.utils.tree import (
    flatten_with_paths,
    leaf_paths,
    path_str,
    tree_from_flat,
    tree_bytes,
    tree_num_params,
)

__all__ = [
    "flatten_with_paths",
    "leaf_paths",
    "path_str",
    "tree_from_flat",
    "tree_bytes",
    "tree_num_params",
]
