"""Pytree path utilities.

Parameters across the framework are nested dicts of arrays (no flax). Every
leaf is addressed by a canonical dotted path string, e.g.
``"blocks.attn.q_proj"`` — these paths are the *function names* of the
FaaSLight analogy: the unit at which reachability is computed and at which
the optional store keys its compressed entries.
"""

from __future__ import annotations

from typing import Any, Callable, Iterable, Mapping

import jax
import numpy as np
from jax.tree_util import (
    DictKey,
    FlattenedIndexKey,
    GetAttrKey,
    SequenceKey,
)


def _key_to_str(k: Any) -> str:
    if isinstance(k, DictKey):
        return str(k.key)
    if isinstance(k, SequenceKey):
        return str(k.idx)
    if isinstance(k, GetAttrKey):
        return str(k.name)
    if isinstance(k, FlattenedIndexKey):
        return str(k.key)
    return str(k)


def path_str(path: tuple) -> str:
    """Canonical dotted string for a jax key path."""
    return ".".join(_key_to_str(k) for k in path)


def flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    """Flatten a pytree into ``[(dotted_path, leaf), ...]`` (sorted order of
    jax's flatten, which is deterministic)."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(path_str(p), v) for p, v in leaves]


def leaf_paths(tree: Any) -> list[str]:
    return [p for p, _ in flatten_with_paths(tree)]


def tree_from_flat(flat: Mapping[str, Any]) -> dict:
    """Rebuild a nested dict from dotted paths. Integer path segments become
    dict keys as-is (we only use dicts, never lists, in param trees)."""
    out: dict = {}
    for path, leaf in flat.items():
        parts = path.split(".")
        node = out
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = leaf
    return out


def _leaf_nbytes(x: Any) -> int:
    if hasattr(x, "nbytes"):
        return int(x.nbytes)
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return 0


def tree_bytes(tree: Any) -> int:
    """Total bytes across leaves (works on arrays and ShapeDtypeStructs)."""
    return sum(_leaf_nbytes(x) for x in jax.tree_util.tree_leaves(tree))


def tree_num_params(tree: Any) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        if hasattr(x, "shape"):
            total += int(np.prod(x.shape)) if x.shape else 1
    return total


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(dotted_path, leaf) -> leaf`` over a pytree."""
    return jax.tree_util.tree_map_with_path(lambda p, v: fn(path_str(p), v), tree)


def select_paths(tree: Any, predicate: Callable[[str], bool]) -> dict:
    """Subset of leaves whose dotted path satisfies ``predicate`` (flat dict)."""
    return {p: v for p, v in flatten_with_paths(tree) if predicate(p)}


def iter_chunks(seq: Iterable, n: int):
    buf = []
    for x in seq:
        buf.append(x)
        if len(buf) == n:
            yield buf
            buf = []
    if buf:
        yield buf


def flatten_axes_tree(tree: Any) -> list[tuple[str, Any]]:
    """Flatten a tree whose *leaves are tuples* (e.g. logical-axis tuples).
    The generic flatten would recurse into the tuples; this one stops at
    non-dict nodes."""
    out = []

    def rec(node, prefix):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, f"{prefix}.{k}" if prefix else str(k))
        else:
            out.append((prefix, node))

    rec(tree, "")
    return out
