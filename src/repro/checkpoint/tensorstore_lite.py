"""Raw-binary array bundle: dtype-faithful (bf16-safe), partially readable.

One bundle = ``<prefix>.bin`` (concatenated raw buffers, 64-byte aligned)
+ ``<prefix>.index.json`` ({path: {offset, shape, dtype}}). Unlike npz this
round-trips ml_dtypes (bfloat16/fp8) exactly and supports reading a subset
of keys without touching the rest of the file — the property both the
two-tier cold start (tier-0 subset reads) and sharded restore (per-host
slices) rely on.

Writes are atomic: ``.partial`` + rename, index last — a crashed writer can
never produce a bundle with an index pointing at truncated data.
"""

from __future__ import annotations

import json
import os
from typing import Iterable, Mapping, Optional

import numpy as np

_ALIGN = 64


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def write_bundle(prefix: str, arrays: Mapping[str, np.ndarray]) -> dict:
    """Write all arrays; returns the index. Atomic (bin first, index last)."""
    bin_tmp = prefix + ".bin.partial"
    index: dict[str, dict] = {}
    offset = 0
    with open(bin_tmp, "wb") as f:
        for key, arr in arrays.items():
            arr = np.ascontiguousarray(arr)
            pad = (-offset) % _ALIGN
            if pad:
                f.write(b"\0" * pad)
                offset += pad
            buf = arr.tobytes()
            f.write(buf)
            index[key] = {
                "offset": offset,
                "nbytes": len(buf),
                "shape": list(arr.shape),
                "dtype": np.dtype(arr.dtype).name,
            }
            offset += len(buf)
        f.flush()
        os.fsync(f.fileno())
    os.replace(bin_tmp, prefix + ".bin")
    idx_tmp = prefix + ".index.json.partial"
    with open(idx_tmp, "w") as f:
        json.dump(index, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(idx_tmp, prefix + ".index.json")
    return index


def read_index(prefix: str) -> dict:
    with open(prefix + ".index.json") as f:
        return json.load(f)


def read_bundle(
    prefix: str,
    keys: Optional[Iterable[str]] = None,
    *,
    mmap: bool = True,
) -> dict[str, np.ndarray]:
    """Read (a subset of) a bundle. With ``mmap`` the returned arrays are
    zero-copy views over the page cache — bytes move lazily on first touch,
    which is exactly the access pattern tier-0 device_put wants."""
    index = read_index(prefix)
    sel = list(index) if keys is None else list(keys)
    out: dict[str, np.ndarray] = {}
    if mmap:
        raw = np.memmap(prefix + ".bin", dtype=np.uint8, mode="r")
        for k in sel:
            e = index[k]
            dt = _np_dtype(e["dtype"])
            view = raw[e["offset"] : e["offset"] + e["nbytes"]]
            out[k] = view.view(dt).reshape(e["shape"])
    else:
        with open(prefix + ".bin", "rb") as f:
            for k in sorted(sel, key=lambda k: index[k]["offset"]):
                e = index[k]
                f.seek(e["offset"])
                buf = f.read(e["nbytes"])
                out[k] = np.frombuffer(buf, _np_dtype(e["dtype"])).reshape(e["shape"]).copy()
    return out


def bundle_nbytes(prefix: str) -> int:
    return sum(e["nbytes"] for e in read_index(prefix).values())
