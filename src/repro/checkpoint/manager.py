"""Checkpoint manager: atomic, async, keep-N, deterministic restore.

Layout::

    <dir>/
      manifest.json            # {"latest": 300, "steps": [100, 200, 300]}
      step_00000300/
        params.bin  params.index.json
        opt_state.bin ...
        meta.json              # step, mesh shape, arch, wall time

Fault-tolerance contract (DESIGN.md §6):
  * a step directory becomes visible only via rename, and the manifest is
    updated only after the directory is complete → readers never see a
    torn checkpoint; a crash mid-save leaves the previous manifest intact;
  * ``restore`` validates every leaf's shape/dtype against the expected
    abstract tree before any device transfer — a corrupt or mismatched
    checkpoint fails fast, not 300 steps later;
  * async save: the device→host snapshot is taken synchronously (cheap),
    the disk write happens on a worker thread — training continues while
    bytes land; ``wait()`` joins before the next save or process exit;
  * keep-N GC never deletes the newest *committed* step.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint import tensorstore_lite as tsl
from repro.utils.tree import flatten_with_paths, tree_from_flat


def _host_snapshot(tree: Any) -> dict[str, np.ndarray]:
    """Flatten + device_get a collection tree (the synchronous part)."""
    flat = flatten_with_paths(tree)
    arrs = jax.device_get([v for _, v in flat])
    return {p: np.asarray(a) for (p, _), a in zip(flat, arrs)}


def commit_dir(tmp: str, final: str) -> None:
    """Publish a fully-written directory via rename — the repo-wide commit
    rule (DESIGN.md §6): a reader never sees a half-written ``final``.
    Replacing an existing ``final`` removes it first, so a crash between
    the rmtree and the rename leaves ``final`` *absent* (detectably
    missing, never torn); callers that need the previous version to
    survive that window keep their own commit record (the checkpoint
    manager's manifest) or treat absence as "re-run the rewrite" (the
    profile-guided artifact rewrite, ``core/retier.py`` / DESIGN.md §11.2,
    whose source artifact is never touched)."""
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)


def orphaned_partials(root: str) -> list[str]:
    """Staging directories a crash left behind: every ``*.partial`` dir
    under ``root`` (non-recursive). A ``.partial`` that still exists was
    never renamed into place, so deleting it can never touch a committed
    artifact — that is the whole point of the staging-suffix convention
    (checkpoint step dirs, the daemon's ``-compact`` rewrite, trace
    saves all use it)."""
    try:
        names = sorted(os.listdir(root))
    except FileNotFoundError:
        return []
    return [
        os.path.join(root, n)
        for n in names
        if n.endswith(".partial") and os.path.isdir(os.path.join(root, n))
    ]


def clean_partials(root: str) -> list[str]:
    """Remove every orphaned staging dir under ``root``; returns the paths
    removed. Safe to run concurrently with a writer only at startup —
    callers invoke it before any writer exists (crash recovery)."""
    removed = []
    for p in orphaned_partials(root):
        shutil.rmtree(p, ignore_errors=True)
        removed.append(p)
    return removed


@dataclass
class RestoreResult:
    step: int
    collections: dict
    path: str


class CheckpointManager:
    def __init__(self, directory: str, *, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- manifest -----------------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def _read_manifest(self) -> dict:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return {"latest": None, "steps": []}

    def _write_manifest(self, man: dict) -> None:
        tmp = self._manifest_path() + ".partial"
        with open(tmp, "w") as f:
            json.dump(man, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._manifest_path())

    def latest_step(self) -> Optional[int]:
        return self._read_manifest()["latest"]

    def all_steps(self) -> list[int]:
        return list(self._read_manifest()["steps"])

    # -- save ---------------------------------------------------------------
    def save(self, step: int, collections: dict, *, meta: Optional[dict] = None, blocking: Optional[bool] = None) -> None:
        """Snapshot now; write now (blocking) or on the worker thread."""
        self.wait()  # one in-flight save at a time
        host = {name: _host_snapshot(tree) for name, tree in collections.items()}
        blocking = (not self.async_save) if blocking is None else blocking
        if blocking:
            self._write(step, host, meta or {})
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, meta or {}), daemon=True
            )
            self._thread.start()

    def _write_guarded(self, step: int, host: dict, meta: dict) -> None:
        try:
            self._write(step, host, meta)
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, host: dict, meta: dict) -> None:
        final = self._step_dir(step)
        tmp = final + ".partial"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, arrays in host.items():
            tsl.write_bundle(os.path.join(tmp, name), arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "time": time.time(), **meta}, f)
        commit_dir(tmp, final)  # commit point 1: directory visible
        man = self._read_manifest()
        steps = sorted(set(man["steps"]) | {step})
        self._write_manifest({"latest": max(steps), "steps": steps})  # commit 2
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from e

    def _gc(self) -> None:
        man = self._read_manifest()
        steps = man["steps"]
        if len(steps) <= self.keep_n:
            return
        drop = steps[: -self.keep_n]
        keep = steps[-self.keep_n :]
        self._write_manifest({"latest": man["latest"], "steps": keep})
        for s in drop:
            d = self._step_dir(s)
            if os.path.isdir(d):
                shutil.rmtree(d, ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(
        self,
        step: Optional[int] = None,
        *,
        abstract: Optional[dict] = None,  # {collection: abstract tree} to validate
        mmap: bool = True,
    ) -> Optional[RestoreResult]:
        """Returns None when no committed checkpoint exists (fresh start)."""
        man = self._read_manifest()
        if step is None:
            step = man["latest"]
        if step is None:
            return None
        if step not in man["steps"]:
            raise FileNotFoundError(f"step {step} not in manifest {man['steps']}")
        d = self._step_dir(step)
        collections = {}
        for name in sorted(os.listdir(d)):
            if not name.endswith(".index.json"):
                continue
            cname = name[: -len(".index.json")]
            flat = tsl.read_bundle(os.path.join(d, cname), mmap=mmap)
            collections[cname] = tree_from_flat(flat)
        if abstract is not None:
            _validate(collections, abstract)
        return RestoreResult(step=step, collections=collections, path=d)


def _validate(collections: dict, abstract: dict) -> None:
    for cname, atree in abstract.items():
        if cname not in collections:
            raise ValueError(f"checkpoint missing collection {cname!r}")
        got = dict(flatten_with_paths(collections[cname]))
        for path, leaf in flatten_with_paths(atree):
            if path not in got:
                raise ValueError(f"{cname}: missing leaf {path}")
            g = got[path]
            if tuple(g.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{cname}.{path}: shape {tuple(g.shape)} != expected {tuple(leaf.shape)}"
                )
            if np.dtype(g.dtype) != np.dtype(leaf.dtype):
                raise ValueError(
                    f"{cname}.{path}: dtype {g.dtype} != expected {leaf.dtype}"
                )
