"""Checkpointing: atomic/async/keep-N manager over a bf16-safe raw-binary
array bundle format with partial reads (tier-aware cold start)."""

from repro.checkpoint.manager import (
    CheckpointManager,
    RestoreResult,
    clean_partials,
    commit_dir,
    orphaned_partials,
)
from repro.checkpoint.tensorstore_lite import (
    bundle_nbytes,
    read_bundle,
    read_index,
    write_bundle,
)

__all__ = [
    "CheckpointManager",
    "RestoreResult",
    "commit_dir",
    "orphaned_partials",
    "clean_partials",
    "write_bundle",
    "read_bundle",
    "read_index",
    "bundle_nbytes",
]
