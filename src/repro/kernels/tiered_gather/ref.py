"""Pure-jnp oracle for the tiered gather."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tiered_gather_ref(
    table: jax.Array,       # (V, D)
    ids: jax.Array,         # (N,) int32
    group_mask: jax.Array,  # (G,) int32 — 1 = resident
    *,
    group_size: int,
) -> tuple[jax.Array, jax.Array]:
    V, D = table.shape
    in_range = (ids >= 0) & (ids < V)
    safe = jnp.clip(ids, 0, V - 1)
    ok = in_range & (group_mask[safe // group_size] > 0)
    rows = jnp.take(table, safe, axis=0)
    out = jnp.where(ok[:, None], rows, 0)
    miss = (~ok).astype(jnp.int32)
    return out, miss
