"""Pure-jnp oracle for the tiered gather."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tiered_gather_ref(
    table: jax.Array,       # (V, D)
    ids: jax.Array,         # (N,) int32
    group_mask: jax.Array,  # (G,) int32 — 1 = resident
    *,
    group_size: int,
) -> tuple[jax.Array, jax.Array]:
    V, D = table.shape
    in_range = (ids >= 0) & (ids < V)
    safe = jnp.clip(ids, 0, V - 1)
    ok = in_range & (group_mask[safe // group_size] > 0)
    rows = jnp.take(table, safe, axis=0)
    out = jnp.where(ok[:, None], rows, 0)
    miss = (~ok).astype(jnp.int32)
    return out, miss


def tiered_gather_matmul_ref(
    table: jax.Array,       # (V, D)
    w: jax.Array,           # (D, F)
    ids: jax.Array,         # (N,) int32
    group_mask: jax.Array,  # (G,) int32 — 1 = resident
    *,
    group_size: int,
) -> tuple[jax.Array, jax.Array]:
    """Dense reference for the fused kernel: gather (zeros for misses),
    then matmul at full width — exactly the two-step path the fusion
    replaces. Accumulates fp32 like the kernel so resident rows agree to
    reduction-order rounding and miss rows are exactly zero."""
    rows, miss = tiered_gather_ref(table, ids, group_mask, group_size=group_size)
    out = jnp.einsum(
        "nd,df->nf", rows.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(table.dtype)
    return out, miss
