"""Jit'd wrapper for the tiered gather."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tiered_gather.kernel import (
    tiered_gather_matmul_pallas,
    tiered_gather_pallas,
)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("group_size", "interpret"))
def tiered_gather(
    table: jax.Array,
    ids: jax.Array,
    group_mask: jax.Array,
    *,
    group_size: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Gather rows with residency check. Returns (rows (N, D) — zeros for
    misses, miss (N,) int32)."""
    if interpret is None:
        interpret = not _is_tpu()
    ids = ids.astype(jnp.int32)
    group_mask = group_mask.astype(jnp.int32)
    return tiered_gather_pallas(
        table, ids, group_mask, group_size=group_size, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("group_size", "interpret"))
def tiered_gather_matmul(
    table: jax.Array,
    w: jax.Array,
    ids: jax.Array,
    group_mask: jax.Array,
    *,
    group_size: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused residency-masked gather→matmul (DESIGN.md §16.1). Returns
    (out (N, F) — table[ids] @ w with zeros for misses, miss (N,) int32);
    cold rows are skipped (no DMA, no MXU work), not zero-filled-and-
    multiplied."""
    if interpret is None:
        interpret = not _is_tpu()
    ids = ids.astype(jnp.int32)
    group_mask = group_mask.astype(jnp.int32)
    return tiered_gather_matmul_pallas(
        table, w, ids, group_mask, group_size=group_size, interpret=interpret
    )
