"""Pallas TPU tiered row-gather — the FaaSLight on-demand data plane.

Embedding/readout tables under the two-tier scheme have *resident* row
groups (tier-0 / already faulted-in) and *cold* groups whose device rows are
placeholders. The serving engine needs, per token-id batch: the gathered
rows for resident ids, and a miss mask telling it which ids touched cold
groups (→ fault the group in via the on-demand loader and retry — the
``rewrite_template`` control flow, at kernel level).

TPU adaptation: a data-dependent gather on TPU is expressed through
*scalar-prefetched* indices — the ids (and the residency bitmap) are given
to the grid pipeline up front (SMEM), and the table's BlockSpec index_map
selects row ``ids[i]`` for grid step ``i``, so each row move is a pipelined
HBM→VMEM DMA issued by the grid machinery itself (no gather instruction on
the VPU at all; this is how TPU embedding lookups are structured). Cold ids
are clamped to row 0 in the index_map (a always-valid DMA) and zeroed in
the body, so the pipeline never reads out of bounds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tiered_gather_kernel(
    ids_ref,   # (N,) int32   — scalar prefetch
    mask_ref,  # (G,) int32   — scalar prefetch (1 = group resident)
    table_ref, # (1, D) block — row ids[i] (clamped) of the table
    o_ref,     # (1, D) block
    miss_ref,  # (1, 1) block int32
    *,
    group_size: int,
    n_rows: int,
):
    i = pl.program_id(0)
    idx = ids_ref[i]
    in_range = jnp.logical_and(idx >= 0, idx < n_rows)
    grp = jnp.clip(idx, 0, n_rows - 1) // group_size
    ok = jnp.logical_and(in_range, mask_ref[grp] > 0)
    row = table_ref[0, :]
    o_ref[0, :] = jnp.where(ok, row, jnp.zeros_like(row))
    miss_ref[0, 0] = jnp.where(ok, 0, 1).astype(jnp.int32)


def _tiered_gather_matmul_kernel(
    ids_ref,    # (N,) int32   — scalar prefetch
    mask_ref,   # (G,) int32   — scalar prefetch (1 = group resident)
    fetch_ref,  # (N,) int32   — scalar prefetch: row actually DMA'd at step i
    table_ref,  # (1, D) block — row fetch_ref[i] of the table
    w_ref,      # (D, F) block — the expert weight, whole
    o_ref,      # (1, F) block
    miss_ref,   # (1, 1) block int32
    *,
    group_size: int,
    n_rows: int,
):
    i = pl.program_id(0)
    idx = ids_ref[i]
    in_range = jnp.logical_and(idx >= 0, idx < n_rows)
    grp = jnp.clip(idx, 0, n_rows - 1) // group_size
    ok = jnp.logical_and(in_range, mask_ref[grp] > 0)
    miss_ref[0, 0] = jnp.where(ok, 0, 1).astype(jnp.int32)

    # skip, don't zero-and-compute: a cold step writes zeros and never
    # touches the MXU (and its DMA was elided by the fetch-id scheme —
    # see tiered_gather_matmul_pallas)
    @pl.when(ok)
    def _matmul():
        o_ref[...] = jax.lax.dot_general(
            table_ref[...].astype(jnp.float32),
            w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(o_ref.dtype)

    @pl.when(jnp.logical_not(ok))
    def _cold():
        o_ref[...] = jnp.zeros_like(o_ref)


def tiered_gather_matmul_pallas(
    table: jax.Array,  # (V, D)
    w: jax.Array,      # (D, F) expert weight
    ids: jax.Array,    # (N,) int32
    group_mask: jax.Array,  # (G,) int32
    *,
    group_size: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused residency-masked gather→expert-matmul (DESIGN.md §16.1).

    out[i] = table[ids[i]] @ w for resident in-range ids, zeros otherwise;
    miss[i] = 1 exactly where the row was cold/out-of-range (the loader's
    fault-and-retry signal, same contract as ``tiered_gather``).

    Device-byte/FLOP saving vs gather-then-matmul: the grid pipeline only
    re-issues a row DMA when the block index *changes* between steps, so
    cold steps prefetch ``fetch_ids[i]`` — the most recent resident row
    (row 0 before any) — instead of a clamped fresh row: a run of cold ids
    repeats the previous index and moves no HBM bytes. The matmul body is
    gated with ``pl.when(ok)``, so cold rows are never multiplied either
    (the old path zero-filled and then multiplied at full width).
    """
    V, D = table.shape
    F = w.shape[1]
    N = ids.shape[0]
    safe = jnp.clip(ids, 0, V - 1)
    in_range = jnp.logical_and(ids >= 0, ids < V)
    ok = jnp.logical_and(in_range, group_mask[safe // group_size] > 0)
    # last-known-resident scan: cold steps re-request the previous resident
    # row so the pipeline's change-detection elides their copy entirely
    last_ok = jax.lax.cummax(jnp.where(ok, jnp.arange(N, dtype=jnp.int32), -1))
    fetch_ids = jnp.where(last_ok >= 0, safe[jnp.maximum(last_ok, 0)], 0)

    kernel = functools.partial(
        _tiered_gather_matmul_kernel, group_size=group_size, n_rows=V
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N,),
        in_specs=[
            pl.BlockSpec(
                (1, D),
                lambda i, ids_ref, mask_ref, fetch_ref: (fetch_ref[i], 0),
            ),
            # whole weight, same block every step: DMA'd once, then elided
            pl.BlockSpec(
                (D, F),
                lambda i, ids_ref, mask_ref, fetch_ref: (0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, F), lambda i, ids_ref, mask_ref, fetch_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, ids_ref, mask_ref, fetch_ref: (i, 0)),
        ],
    )
    out, miss = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((N, F), table.dtype),
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
        ],
        interpret=interpret,
    )(ids, group_mask, fetch_ids, table, w)
    return out, miss[:, 0]


def tiered_gather_pallas(
    table: jax.Array,  # (V, D)
    ids: jax.Array,    # (N,) int32
    group_mask: jax.Array,  # (G,) int32
    *,
    group_size: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    V, D = table.shape
    N = ids.shape[0]
    kernel = functools.partial(_tiered_gather_kernel, group_size=group_size, n_rows=V)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N,),
        in_specs=[
            # dynamic-block gather: row ids[i] (clamped into range) per step
            pl.BlockSpec(
                (1, D),
                lambda i, ids_ref, mask_ref: (
                    jnp.clip(ids_ref[i], 0, V - 1),
                    0,
                ),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda i, ids_ref, mask_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, ids_ref, mask_ref: (i, 0)),
        ],
    )
    out, miss = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((N, D), table.dtype),
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
        ],
        interpret=interpret,
    )(ids, group_mask, table)
    return out, miss[:, 0]
