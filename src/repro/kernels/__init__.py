"""Pallas TPU kernels for the serving/training hot paths (DESIGN.md §7).

Each subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jit'd public wrapper with layout/padding/interpret fallback)
and ``ref.py`` (pure-jnp oracle used by the allclose test sweeps):

  flash_attention — prefill/train attention (online softmax, causal/SWA/GQA)
  decode_attention — flash-decode over KV caches (linear + rolling)
  rglru_scan      — RG-LRU blocked linear recurrence
  tiered_gather   — two-tier row gather with miss mask (the paper's
                    on-demand loading expressed at kernel level)

Kernels are TARGETed at TPU and validated with interpret=True on CPU. The
dry-run/roofline path intentionally lowers the pure-jnp implementations
(``use_pallas=False``) so ``cost_analysis()`` sees real FLOPs — a Pallas
custom-call is opaque to XLA's cost model.
"""
