"""Pure-jnp oracle: the sequential linear recurrence, scanned step by step
(numerically the ground truth; the model layer's associative scan and the
Pallas blocked scan must both match it)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """s_t = a_t * s_{t-1} + b_t, s_{-1} = 0. a, b: (B, S, W)."""

    def step(s, ab):
        at, bt = ab
        s = at * s + bt
        return s, s

    B, S, W = a.shape
    s0 = jnp.zeros((B, W), a.dtype)
    _, out = jax.lax.scan(step, s0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return out.swapaxes(0, 1)
