"""Pallas TPU blocked linear-recurrence scan for RG-LRU (RecurrentGemma).

Computes ``s_t = a_t ⊙ s_{t-1} + b_t`` over time, given precomputed decay
``a`` and input ``b`` (the gate math stays in XLA where it fuses with the
projections; the kernel owns only the serial dependency).

TPU adaptation: the GPU implementations (e.g. the Griffin CUDA scan) use
warp-parallel chunked prefix products; on TPU we tile (time, width) into
(bt, bw) VMEM blocks, run the recurrence *sequentially over the innermost
time-grid dimension* with the carried state in VMEM scratch, and keep the
width dimension fully vectorized on the VPU (8×128 lanes). Within a block
the loop over bt rows is a scalar-time / vector-width fori_loop — the
recurrence is elementwise in width, so the MXU is not involved and the
kernel is purely bandwidth-bound (as is the op itself: 3 streams in, 1
out).

Grid: (B, nW, nT) — nT innermost; scratch carries (1, bw) state across
time blocks of the same (batch, width) lane group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, s_scr, *, bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    a = a_ref[0]  # (bt, bw) fp32
    b = b_ref[0]

    def step(t, s):
        s = a[t, :][None, :] * s + b[t, :][None, :]  # (1, bw)
        o_ref[0, t, :] = s[0, :].astype(o_ref.dtype)
        return s

    s = jax.lax.fori_loop(0, bt, step, s_scr[...])
    s_scr[...] = s


def rglru_scan_pallas(
    a: jax.Array,  # (B, S, W) fp32 decay
    b: jax.Array,  # (B, S, W) fp32 input
    *,
    bt: int = 256,
    bw: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, W = a.shape
    nt = S // bt
    nw = W // bw
    kernel = functools.partial(_rglru_kernel, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
        ],
        out_specs=pl.BlockSpec((1, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b)
