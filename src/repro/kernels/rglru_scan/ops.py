"""Jit'd wrapper: padding to block multiples + interpret fallback."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bt", "bw", "interpret"))
def rglru_scan(
    a: jax.Array,  # (B, S, W) decay in [0, 1)
    b: jax.Array,  # (B, S, W)
    *,
    bt: int = 256,
    bw: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _is_tpu()
    B, S, W = a.shape
    bt = min(bt, max(8, 1 << (S - 1).bit_length()))
    bw = min(bw, max(128, 1 << (W - 1).bit_length()))
    pad_t = (-S) % bt
    pad_w = (-W) % bw
    # time padding appends steps (a=0, b=0) after the real sequence — the
    # padded outputs are garbage but sliced off; width padding adds dead lanes.
    ap = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_w)))
    bp = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_w)))
    out = rglru_scan_pallas(
        ap.astype(jnp.float32), bp.astype(jnp.float32), bt=bt, bw=bw, interpret=interpret
    )
    return out[:, :S, :W].astype(a.dtype)
