"""Pure-jnp oracle for flash-decode (thin re-export of the model-layer
implementation, which is itself the naive ground truth for one-token
attention over a cache)."""

from __future__ import annotations

from typing import Optional

import jax

from repro.models.attention import decode_attention_jnp


def paged_decode_attention_ref(
    q: jax.Array,        # (B, H, hd)
    k_pages: jax.Array,  # (P, ps, Hkv, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, NP) int32
    kv_len: jax.Array,
    *,
    rolling: bool = False,
    softcap: Optional[float] = None,
) -> jax.Array:
    from repro.models.attention import decode_attention_paged_jnp

    return decode_attention_paged_jnp(
        q, k_pages, v_pages, page_table, kv_len, rolling=rolling, softcap=softcap
    )


def decode_attention_ref(
    q: jax.Array,       # (B, H, hd)
    k_cache: jax.Array, # (B, Skv, Hkv, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,
    *,
    rolling: bool = False,
    softcap: Optional[float] = None,
) -> jax.Array:
    return decode_attention_jnp(
        q, k_cache, v_cache, kv_len, rolling=rolling, softcap=softcap
    )
