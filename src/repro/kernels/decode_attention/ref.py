"""Pure-jnp oracle for flash-decode (thin re-export of the model-layer
implementation, which is itself the naive ground truth for one-token
attention over a cache)."""

from __future__ import annotations

from typing import Optional

import jax

from repro.models.attention import decode_attention_jnp


def decode_attention_ref(
    q: jax.Array,       # (B, H, hd)
    k_cache: jax.Array, # (B, Skv, Hkv, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,
    *,
    rolling: bool = False,
    softcap: Optional[float] = None,
) -> jax.Array:
    return decode_attention_jnp(
        q, k_cache, v_cache, kv_len, rolling=rolling, softcap=softcap
    )
