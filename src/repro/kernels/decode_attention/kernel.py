"""Pallas TPU flash-decode: one-token attention over a long KV cache.

The decode step is memory-bound: the entire KV cache streams HBM→VMEM once
per token while compute is a (H, hd)×(hd, bk) matvec per block. The kernel
tiles the KV sequence into (bk, hd) VMEM blocks on the innermost sequential
grid dimension with the usual online-softmax carry in scratch; all query
heads of one KV-head group are processed together so each KV block is
fetched exactly once (GQA arithmetic-intensity optimization — G×hd rows of
q amortize one KV block load).

Grid: (B, Hkv, nk). Cache layout (B, Hkv, Skv, hd) — the serving engine
keeps caches in this layout so no transpose sits on the decode hot path.
``kv_len`` masks both linear caches (valid prefix) and rolling caches
(every slot valid once wrapped; softmax is permutation-invariant).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(
    len_ref,  # (1, 1) int32 — valid cache length for this batch row
    q_ref,    # (1, 1, G, hd)
    k_ref,    # (1, 1, bk, hd)
    v_ref,    # (1, 1, bk, hd)
    o_ref,    # (1, 1, G, hd)
    m_scr, l_scr, acc_scr,  # (G, 1), (G, 1), (G, hd)
    *,
    scale: float,
    softcap: Optional[float],
    rolling: bool,
    skv: int,
    bk: int,
    nk: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # the wrapper pre-clamps rolling caches: limit = min(kv_len, true_skv)
    limit = len_ref[0, 0]
    needed = ki * bk < limit

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = k_pos < limit  # (1, bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_decode_kernel(
    pt_ref,   # (B, NP) int32 — scalar prefetch: physical page per logical page
    len_ref,  # (B,) int32    — scalar prefetch: valid cache length per slot
    q_ref,    # (1, 1, G, hd)
    k_ref,    # (1, ps, 1, hd) — one physical page, one KV head
    v_ref,    # (1, ps, 1, hd)
    o_ref,    # (1, 1, G, hd)
    m_scr, l_scr, acc_scr,  # (G, 1), (G, 1), (G, hd)
    *,
    scale: float,
    softcap: Optional[float],
    ps: int,
    np_max: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    limit = len_ref[b]
    needed = ki * ps < limit

    @pl.when(needed)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (ps, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, ps)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        mask = k_pos < limit  # (1, ps) — partial last page
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == np_max - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array,        # (B, Hkv, G, hd)
    k_pages: jax.Array,  # (P, ps, Hkv, hd) — global page pool
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, NP) int32 — pre-clamped (see ops.py)
    kv_len: jax.Array,   # (B,) int32
    *,
    softcap: Optional[float],
    interpret: bool = False,
) -> jax.Array:
    """Paged flash-decode (DESIGN.md §16.2): the KV cache lives in a
    global pool of fixed-size pages; each slot owns the physical pages its
    ``page_table`` row names, in logical order. The inner grid walks the
    slot's logical pages and the k/v BlockSpec index_maps chase
    ``page_table[b, ki]``, so each step DMAs ONE page — a slot pays
    bytes for the pages it occupies, not for the max decode shape.

    Grid steps past the slot's last occupied page re-request that same
    page (the wrapper clamps the table), so the pipeline's block-index
    change detection elides their copies; ``pl.when`` skips their compute.
    """
    B, Hkv, G, hd = q.shape
    _, ps, _, _ = k_pages.shape
    NP = page_table.shape[1]
    scale = hd**-0.5

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, softcap=softcap, ps=ps, np_max=NP
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, NP),
        in_specs=[
            pl.BlockSpec(
                (1, 1, G, hd), lambda b, h, ki, pt, lens: (b, h, 0, 0)
            ),
            pl.BlockSpec(
                (1, ps, 1, hd), lambda b, h, ki, pt, lens: (pt[b, ki], 0, h, 0)
            ),
            pl.BlockSpec(
                (1, ps, 1, hd), lambda b, h, ki, pt, lens: (pt[b, ki], 0, h, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, ki, pt, lens: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, kv_len, q, k_pages, v_pages)


def decode_attention_pallas(
    q: jax.Array,       # (B, Hkv, G, hd)
    k_cache: jax.Array, # (B, Hkv, Skv, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,  # (B, 1) int32
    *,
    rolling: bool,
    softcap: Optional[float],
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, hd = q.shape
    _, _, Skv_p, _ = k_cache.shape
    nk = Skv_p // bk
    scale = hd**-0.5

    kernel = functools.partial(
        _decode_kernel,
        scale=scale, softcap=softcap, rolling=rolling,
        skv=Skv_p, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k_cache, v_cache)
