"""Jit'd wrapper for flash-decode: layout/padding + GQA fold + interpret
fallback. Accepts the model layer's (B, Skv, Hkv, hd) cache layout."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("rolling", "softcap", "bk", "interpret"))
def decode_attention(
    q: jax.Array,       # (B, H, hd)
    k_cache: jax.Array, # (B, Skv, Hkv, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,  # scalar or (B,)
    *,
    rolling: bool = False,
    softcap: Optional[float] = None,
    bk: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _is_tpu()
    B, H, hd = q.shape
    _, Skv, Hkv, _ = k_cache.shape
    G = H // Hkv

    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len, jnp.int32)
    # clamp to the physical cache: rolling caches wrap (every slot valid once
    # kv_len >= Skv) and linear caches can never hold more than Skv entries —
    # either way padded slots past Skv must stay masked.
    kv_len = jnp.minimum(kv_len, Skv).reshape(B, 1)

    bk = min(bk, max(128, 1 << (Skv - 1).bit_length()))
    pad = (-Skv) % bk
    kc = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k_cache
    vc = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v_cache

    qf = q.reshape(B, Hkv, G, hd)
    kf = kc.transpose(0, 2, 1, 3)  # (B, Hkv, Skv_p, hd)
    vf = vc.transpose(0, 2, 1, 3)

    o = decode_attention_pallas(
        qf, kf, vf, kv_len,
        rolling=rolling, softcap=softcap, bk=bk, interpret=interpret,
    )
    return o.reshape(B, H, hd)
