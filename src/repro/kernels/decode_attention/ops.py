"""Jit'd wrapper for flash-decode: layout/padding + GQA fold + interpret
fallback. Accepts the model layer's (B, Skv, Hkv, hd) cache layout."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas,
    paged_decode_attention_pallas,
)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("rolling", "softcap", "bk", "interpret"))
def decode_attention(
    q: jax.Array,       # (B, H, hd)
    k_cache: jax.Array, # (B, Skv, Hkv, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,  # scalar or (B,)
    *,
    rolling: bool = False,
    softcap: Optional[float] = None,
    bk: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _is_tpu()
    B, H, hd = q.shape
    _, Skv, Hkv, _ = k_cache.shape
    G = H // Hkv

    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len, jnp.int32)
    # clamp to the physical cache: rolling caches wrap (every slot valid once
    # kv_len >= Skv) and linear caches can never hold more than Skv entries —
    # either way padded slots past Skv must stay masked.
    kv_len = jnp.minimum(kv_len, Skv).reshape(B, 1)

    bk = min(bk, max(128, 1 << (Skv - 1).bit_length()))
    pad = (-Skv) % bk
    kc = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k_cache
    vc = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v_cache

    qf = q.reshape(B, Hkv, G, hd)
    kf = kc.transpose(0, 2, 1, 3)  # (B, Hkv, Skv_p, hd)
    vf = vc.transpose(0, 2, 1, 3)

    o = decode_attention_pallas(
        qf, kf, vf, kv_len,
        rolling=rolling, softcap=softcap, bk=bk, interpret=interpret,
    )
    return o.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("rolling", "softcap", "interpret"))
def paged_decode_attention(
    q: jax.Array,        # (B, H, hd)
    k_pages: jax.Array,  # (P, ps, Hkv, hd) — global page pool
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, NP) int32
    kv_len: jax.Array,   # scalar or (B,)
    *,
    rolling: bool = False,
    softcap: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Paged flash-decode wrapper (DESIGN.md §16.2): GQA fold + kv_len
    clamp + page-table tail clamp, then the Pallas kernel. A slot's cache
    capacity is ``NP * ps``; like the dense wrapper, kv_len is clamped to
    it (rolling caches wrap — every allocated slot valid once full)."""
    if interpret is None:
        interpret = not _is_tpu()
    B, H, hd = q.shape
    P, ps, Hkv, _ = k_pages.shape
    NP = page_table.shape[1]
    G = H // Hkv

    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len, jnp.int32)
    kv_len = jnp.minimum(kv_len, NP * ps)

    # clamp the logical tail: steps past the slot's last occupied page
    # re-request that page (DMA elided) instead of chasing a freed/garbage
    # table entry; also bound every entry to the physical pool
    last = jnp.maximum((kv_len + ps - 1) // ps - 1, 0)  # (B,)
    ki = jnp.arange(NP, dtype=jnp.int32)
    logical = jnp.minimum(ki[None, :], last[:, None])   # (B, NP)
    pt = jnp.take_along_axis(page_table.astype(jnp.int32), logical, axis=1)
    pt = jnp.clip(pt, 0, P - 1)

    qf = q.reshape(B, Hkv, G, hd)
    o = paged_decode_attention_pallas(
        qf, k_pages, v_pages, pt, kv_len, softcap=softcap, interpret=interpret
    )
    return o.reshape(B, H, hd)
