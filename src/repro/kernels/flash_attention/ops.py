"""Jit'd public wrapper: layout handling, padding, GQA folding, interpret
fallback on CPU. The model layer calls ``flash_attention``; everything else
in this package is implementation detail."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _is_tpu()
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv

    bq = min(bq, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(bk, max(8, 1 << (Sk - 1).bit_length()))

    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    # (B, S, H, hd) -> (B*H, S, hd); KV heads stay unexpanded (GQA in index_map)
    qf = qp.transpose(0, 2, 1, 3).reshape(B * H, Sq + pad_q, hd)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk + pad_k, hd)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk + pad_k, hd)

    o = flash_attention_pallas(
        qf, kf, vf,
        group=G, causal=causal, window=window, softcap=softcap,
        q_offset=q_offset, sq=Sq, sk=Sk, bq=bq, bk=bk, interpret=interpret,
    )
    o = o.reshape(B, H, Sq + pad_q, hd).transpose(0, 2, 1, 3)
    return o[:, :Sq]
