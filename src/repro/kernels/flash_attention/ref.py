"""Pure-jnp oracle for the flash-attention kernel.

Deliberately the *naive* formulation (materialized (Sq, Sk) scores, fp32
softmax) — numerically the ground truth the online-softmax kernel must
match. The model code's chunked implementation
(repro.models.attention.flash_attention_jnp) is itself validated against
this oracle in tests, closing the loop kernel ↔ chunked-jnp ↔ naive.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def attention_ref(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    softcap: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    scale = hd**-0.5
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
