"""Pallas TPU flash-attention (prefill/train): blockwise online softmax.

TPU adaptation (DESIGN.md §7): the classic GPU flash-attention tiles over
SM shared memory with warp-level reductions; the TPU version tiles over
VMEM with (bq, bk) score blocks sized as MXU-aligned 128-multiples, and the
online max/denominator carry lives in VMEM scratch that persists across the
*sequential* innermost grid dimension (TPU grids execute in order, which
replaces the GPU's atomic/semaphore accumulation).

Grid: (B·H, nq, nk) — nk innermost/sequential. GQA is expressed in the
k/v index_map (``bh // group``) so KV blocks are fetched once per KV head
group, not once per query head.

Causal/windowed blocks that are fully masked are skipped with ``pl.when``
(no MXU work issued), matching the exact-triangle FLOP accounting of the
jnp oracle.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1, bq, hd), (1, bk, hd), (1, bk, hd)
    o_ref,  # (1, bq, hd)
    m_scr, l_scr, acc_scr,  # (bq, 1), (bq, 1), (bq, hd) fp32 VMEM
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    q_offset: int,
    sq: int,
    sk: int,
    bq: int,
    bk: int,
    nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset  # absolute position of this q block
    k_start = ki * bk

    # block-level reachability: skip fully-masked (bq, bk) tiles entirely
    needed = True
    if causal:
        needed = jnp.logical_and(needed, k_start <= q_start + bq - 1)
    if window is not None:
        needed = jnp.logical_and(needed, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(needed)
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < sk  # k padding
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask  # masked lanes contribute exactly 0
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,  # (BHkv, Sk, hd)
    v: jax.Array,
    *,
    group: int,  # H // Hkv
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    q_offset: int,
    sq: int,  # true (unpadded) Sq
    sk: int,  # true (unpadded) Sk
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq_p, hd = q.shape
    _, Sk_p, _ = k.shape
    nq = Sq_p // bq
    nk = Sk_p // bk
    scale = hd**-0.5

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        sq=sq,
        sk=sk,
        bq=bq,
        bk=bk,
        nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
