"""Deterministic synthetic token pipeline: shard-aware, packed, resumable.

Production shape without production data: batches are generated from a
counter-based PRNG (threefry on (seed, shard, step)) so that

  * every (host, step) pair produces the same bytes on every run —
    bitwise-deterministic restart after preemption;
  * shards never overlap: shard ``i`` of ``n`` draws from a key folded with
    ``i`` — the data-parallel axes of the production mesh each consume a
    disjoint stream;
  * resuming from step k needs no cursor replay — state is just ``step``
    (persisted in the checkpoint's ``data_state`` collection, which the
    FaaSLight file-elimination stage drops from serving artifacts).

The token distribution is Zipfian (s≈1.1, like natural text) so vocab-row
access statistics are realistic — the cold/hot row-group split measured by
the RQ benchmarks sees a natural long tail, and "sequence packing" splices
a few document boundaries (EOS) per sequence at deterministic positions.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_s: float = 1.1
    eos_id: int = 0
    mean_doc_len: int = 512


class SyntheticTokenPipeline:
    """Iterator of {"tokens": (B, S) i32, "labels": (B, S) i32} batches.

    ``shard``/``num_shards`` split the *batch dimension*: each shard emits
    its (B/num_shards, S) slice. ``batch_at(step)`` is random access — the
    resume path and the straggler-replay path both use it.
    """

    def __init__(self, cfg: DataConfig, *, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0, (cfg.global_batch, num_shards)
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        # Zipf CDF over the vocab (host-side, float64, computed once)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_s)
        self._cdf = np.cumsum(w) / np.sum(w)

    def _tokens(self, step: int) -> np.ndarray:
        """Deterministic (local_batch, S+1) token block for this shard."""
        cfg = self.cfg
        ss = np.random.SeedSequence([cfg.seed, self.shard, step])
        rng = np.random.Generator(np.random.Philox(ss))
        u = rng.random((self.local_batch, cfg.seq_len + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        # packing: deterministic document boundaries → EOS tokens
        n_docs = max(1, cfg.seq_len // cfg.mean_doc_len)
        bounds = rng.integers(1, cfg.seq_len, size=(self.local_batch, n_docs))
        rows = np.repeat(np.arange(self.local_batch), n_docs)
        toks[rows, bounds.ravel()] = cfg.eos_id
        return toks

    def batch_at(self, step: int) -> dict:
        toks = self._tokens(step)
        return {
            "tokens": toks[:, :-1].copy(),
            "labels": toks[:, 1:].copy(),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def iterate_from(self, step: int) -> Iterator[dict]:
        while True:
            yield self.batch_at(step)
            step += 1

    # -- offline stats (the paper's profiling of module-init functions) -----
    def vocab_row_stats(self, n_steps: int = 4, row_group: int = 2048) -> dict[str, float]:
        """Row-group hotness from a short offline profile — feeds the
        stats residency policy (DESIGN.md §4.2)."""
        counts = np.zeros(int(np.ceil(self.cfg.vocab_size / row_group)))
        for s in range(n_steps):
            toks = self._tokens(s)
            groups, c = np.unique(toks // row_group, return_counts=True)
            counts[groups] += c
        total = counts.sum() or 1.0
        return {f"embed#rg{g}": float(c / total) for g, c in enumerate(counts)}
