from repro.training.pipeline import gpipe_forward, gpipe_loss_fn
from repro.training.train_loop import (
    TrainConfig,
    Trainer,
    TrainResult,
    make_train_step,
    reshard_for_mesh,
)
from repro.training.watchdog import StragglerWatchdog

__all__ = [
    "TrainConfig",
    "Trainer",
    "TrainResult",
    "make_train_step",
    "reshard_for_mesh",
    "StragglerWatchdog",
    "gpipe_forward",
    "gpipe_loss_fn",
]
