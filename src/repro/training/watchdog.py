"""Straggler watchdog: per-step wall-time anomaly detection.

At pod scale a single slow host stretches every synchronous step. The
watchdog keeps an EWMA estimate of step-time mean/variance and flags steps
whose z-score exceeds a threshold; the training loop logs flags and (policy
``skip-log``) continues, or (policy ``abort``) raises so the outer launcher
can reschedule the job — the standard mitigation ladder when you cannot
deschedule a single host from inside the program.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass
class StragglerWatchdog:
    z_threshold: float = 4.0
    ewma_alpha: float = 0.05
    warmup_steps: int = 5
    policy: str = "skip-log"  # skip-log | abort

    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if the step was flagged."""
        self._n += 1
        if self._n <= self.warmup_steps:
            # bootstrap the estimate
            if self._n == 1:
                self._mean = dt
                self._var = (0.5 * dt) ** 2
            else:
                a = 1.0 / self._n
                self._var = (1 - a) * self._var + a * (dt - self._mean) ** 2
                self._mean = (1 - a) * self._mean + a * dt
            return False
        std = math.sqrt(max(self._var, 1e-18))
        z = (dt - self._mean) / std
        flag = z > self.z_threshold
        if flag:
            self.flagged.append((step, dt, z))
            if self.policy == "abort":
                raise RuntimeError(
                    f"straggler watchdog: step {step} took {dt:.3f}s "
                    f"(z={z:.1f} > {self.z_threshold}); aborting for reschedule"
                )
        else:
            # only non-flagged steps update the estimate (a straggler must
            # not poison its own detector)
            a = self.ewma_alpha
            self._var = (1 - a) * self._var + a * (dt - self._mean) ** 2
            self._mean = (1 - a) * self._mean + a * dt
        return flag

    @property
    def mean_step_s(self) -> float:
        return self._mean
