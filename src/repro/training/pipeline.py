"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Expressed jax-natively (DESIGN.md hardware-adaptation note): instead of
emulating NCCL send/recv ranks, the schedule is a single SPMD program under
``shard_map`` — each device holds one stage's parameters (leading dim
sharded over ``stage``) and the classic (n_micro + n_stages - 1)-tick
GPipe wavefront moves activations between neighbours with
``lax.ppermute``. The program is differentiable end to end (ppermute
transposes to the reverse permute), so pipeline *training* falls out of
``jax.grad`` without a hand-written backward schedule.

Off in the assigned production meshes (which use DP×TP; see launch/mesh),
tested separately on a forced multi-device CPU mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe_forward(
    stage_fn: Callable,  # (stage_params, x (mb, d)) -> (mb, d)
    stacked_params,      # pytree; leaves (n_stages, ...) — one slice per stage
    x: jax.Array,        # (n_micro, mb, d) microbatched input
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Returns (n_micro, mb, d) outputs of the full stage chain."""
    n_stages = mesh.shape[axis]

    def spmd(local_params, x_all):
        # local_params leaves: (1, ...) — this device's stage slice
        local_params = jax.tree.map(lambda p: p[0], local_params)
        stage = jax.lax.axis_index(axis)
        n_micro = x_all.shape[0]
        T = n_micro + n_stages - 1
        out = jnp.zeros_like(x_all)
        buf = jnp.zeros(x_all.shape[1:], x_all.dtype)

        def tick(t, carry):
            buf, out = carry
            # stage 0 injects microbatch t; others consume the neighbour's buf
            inject = x_all[jnp.clip(t, 0, n_micro - 1)]
            cur = jnp.where(stage == 0, inject, buf)
            y = stage_fn(local_params, cur)
            # last stage commits microbatch (t - n_stages + 1) when valid
            idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            commit = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            out = out.at[idx].set(jnp.where(commit, y, out[idx]))
            # wavefront: activation moves to the next stage
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, out

        buf, out = jax.lax.fori_loop(0, T, tick, (buf, out))
        # replicate the last stage's result to every shard
        mask = (stage == n_stages - 1).astype(out.dtype)
        return jax.lax.psum(out * mask, axis)

    pspecs = jax.tree.map(lambda _: P(axis), stacked_params)
    fn = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stacked_params, x)


def gpipe_loss_fn(
    stage_fn: Callable,
    readout_fn: Callable,  # (last_hidden (n_micro, mb, d), labels) -> scalar
) -> Callable:
    """Differentiable pipeline loss: grads flow backward through the
    ppermute chain automatically."""

    def loss(stacked_params, x, labels, mesh, axis="stage"):
        h = gpipe_forward(stage_fn, stacked_params, x, mesh, axis)
        return readout_fn(h, labels)

    return loss
