"""Training loop: sharded train step + fault tolerance + elasticity.

The step function is mesh-generic: under a mesh it jits with NamedSharding
in/out specs derived from the logical-axis rules (repro.sharding); without
one it is a plain single-device jit (CPU smoke tests, the e2e example).

Fault tolerance (DESIGN.md §6):
  * restore-on-start from the latest committed checkpoint (manifest-atomic,
    see repro.checkpoint) — a preempted job resumes bitwise-identically
    (params, optimizer moments, data cursor = step);
  * async checkpointing every ``save_every`` steps;
  * straggler watchdog on step wall times;
  * elastic restart: ``reshard_for_mesh`` re-lays-out a restored host
    checkpoint for a *different* mesh/data-axis size — scale-down/up resumes
    without conversion tools.

Gradient accumulation: ``micro_batches > 1`` scans over microbatch slices
accumulating fp32 grads — the global batch stays constant while per-step
activation memory drops by the same factor (the knob the §Perf memory
iterations turn).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.zoo import Model
from repro.optim import (
    AdamWConfig,
    AdamWState,
    adamw_update,
    global_norm,
    init_adamw,
    warmup_cosine,
)
from repro.sharding import param_shardings, use_mesh
from repro.training.watchdog import StragglerWatchdog
from repro.utils.tree import flatten_with_paths


@dataclass
class TrainConfig:
    num_steps: int = 100
    save_every: int = 50
    log_every: int = 10
    micro_batches: int = 1
    adamw: AdamWConfig = field(default_factory=AdamWConfig)
    warmup_steps: int = 10
    seed: int = 0


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    sched = warmup_cosine(tcfg.adamw.lr, tcfg.warmup_steps, tcfg.num_steps)
    n_micro = tcfg.micro_batches

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def step_fn(params, opt_state: AdamWState, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # grad accumulation: scan microbatch slices, fp32 accumulators
            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_l, acc_g = acc
                acc_g = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_l + l, acc_g), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), batch
            )
            (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros), mbs)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        lr = sched(opt_state.step)
        gnorm = global_norm(grads)
        params, opt_state = adamw_update(tcfg.adamw, grads, opt_state, params, lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return step_fn


def reshard_for_mesh(host_collections: dict, mesh, model: Model, *, fsdp: bool = True) -> dict:
    """Elastic restart: place a restored *host* checkpoint onto a (possibly
    different-size) mesh. Parameters follow the logical-axis rules; the
    optimizer moments follow their parameter's sharding; scalars replicate.
    Works for any data-axis size because checkpoints are stored unsharded
    (gathered host arrays) — the trade the design makes for simplicity at
    this scale; per-host sharded saves slot in at the tsl bundle level."""
    from jax.sharding import NamedSharding, PartitionSpec

    log = model.logical_axes()
    shardings = param_shardings(log, model.abstract(), mesh, fsdp=fsdp)
    flat_sh = dict(flatten_with_paths(shardings))
    out = {}
    for cname, tree in host_collections.items():
        placed = {}
        for path, leaf in flatten_with_paths(tree):
            # params.<p> and opt moments m.<p>/v.<p> share the param sharding
            key = path
            for prefix in ("m.", "v."):
                if path.startswith(prefix):
                    key = path[len(prefix):]
            sh = flat_sh.get(key)
            if sh is None or np.ndim(leaf) == 0:
                sh = NamedSharding(mesh, PartitionSpec())
            placed[path] = jax.device_put(np.asarray(leaf), sh)
        from repro.utils.tree import tree_from_flat

        out[cname] = tree_from_flat(placed)
    return out


@dataclass
class TrainResult:
    final_step: int
    losses: list
    flagged_steps: list
    restored_from: Optional[int]


class Trainer:
    """Checkpointed, watchdogged training driver."""

    def __init__(
        self,
        model: Model,
        tcfg: TrainConfig,
        data: SyntheticTokenPipeline,
        ckpt_dir: str,
        *,
        mesh=None,
        keep_n: int = 3,
    ):
        self.model = model
        self.tcfg = tcfg
        self.data = data
        self.mesh = mesh
        self.mgr = CheckpointManager(ckpt_dir, keep_n=keep_n)
        self.watchdog = StragglerWatchdog()
        self._step_fn = None

    def _jit_step(self):
        if self._step_fn is None:
            self._step_fn = jax.jit(make_train_step(self.model, self.tcfg), donate_argnums=(0, 1))
        return self._step_fn

    def _init_state(self) -> tuple[int, Any, AdamWState]:
        restored = self.mgr.restore()
        if restored is not None:
            params = jax.tree.map(jnp.asarray, restored.collections["params"])
            o = restored.collections["opt_state"]
            opt = AdamWState(step=jnp.asarray(o["step"]), m=jax.tree.map(jnp.asarray, o["m"]), v=jax.tree.map(jnp.asarray, o["v"]))
            return restored.step, params, opt
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        return 0, params, init_adamw(params)

    def run(self, num_steps: Optional[int] = None) -> TrainResult:
        tcfg = self.tcfg
        num_steps = num_steps or tcfg.num_steps
        start, params, opt = self._init_state()
        restored_from = start if start > 0 else None
        step_fn = self._jit_step()
        losses = []
        ctx = use_mesh(self.mesh) if self.mesh is not None else _nullcontext()
        with ctx:
            for step, batch in zip(range(start, num_steps), self.data.iterate_from(start)):
                t0 = time.perf_counter()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, metrics = step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.watchdog.record(step, dt)
                losses.append(loss)
                if (step + 1) % tcfg.save_every == 0 or step + 1 == num_steps:
                    self.mgr.save(
                        step + 1,
                        {
                            "params": params,
                            "opt_state": {"step": opt.step, "m": opt.m, "v": opt.v},
                            "data_state": {"step": jnp.asarray(step + 1)},
                        },
                        meta={"arch": self.model.cfg.name},
                    )
        self.mgr.wait()
        return TrainResult(
            final_step=num_steps,
            losses=losses,
            flagged_steps=list(self.watchdog.flagged),
            restored_from=restored_from,
        )


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
