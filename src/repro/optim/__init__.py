from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    abstract_adamw,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_adamw,
    warmup_cosine,
)
from repro.optim.compression import (
    EFState,
    abstract_error_feedback,
    compressed_psum,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "abstract_adamw",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "init_adamw",
    "warmup_cosine",
    "EFState",
    "abstract_error_feedback",
    "compressed_psum",
    "dequantize_int8",
    "init_error_feedback",
    "quantize_int8",
]
