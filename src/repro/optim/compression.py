"""Gradient compression for the slow (cross-pod / DCN) axis.

int8 quantization with *error feedback*: each step transmits
``q = round(g / scale)`` in int8 and carries the residual ``g - q·scale``
into the next step's gradient, so the quantization error is compensated
rather than accumulated (Seide et al. 1-bit SGD lineage; standard practice
for bandwidth-bound data parallelism at pod scale).

Per-leaf symmetric scaling (max-abs / 127) keeps the quantizer parameter-
free. The all-reduce itself sums int32-accumulated int8 payloads; with the
``pod`` axis of the production mesh (2 pods) the wire format is 4× smaller
than bf16 and 8× smaller than fp32.

Used inside ``shard_map``-decorated train steps via ``compressed_psum``;
outside a mapped context it degrades to a local identity (single-host
smoke tests).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # fp32 pytree, same structure as grads


def init_error_feedback(params: Any) -> EFState:
    return EFState(residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def abstract_error_feedback(abstract_params: Any) -> EFState:
    return EFState(
        residual=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    )


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(int8 payload, fp32 scale). Symmetric max-abs scaling."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads: Any,
    ef: EFState,
    axis_name: Optional[str],
    *,
    denom: Optional[int] = None,
) -> tuple[Any, EFState]:
    """Error-feedback int8 all-reduce over ``axis_name``.

    Returns (mean-reduced fp32 grads, new EF state). When ``axis_name`` is
    None (single-pod mesh) this is exact pass-through with zero residual.
    """
    if axis_name is None:
        return jax.tree.map(lambda g: g.astype(jnp.float32), grads), ef

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq_local = dequantize_int8(q, scale)
        new_r = g32 - deq_local  # residual: what this step failed to transmit
        # wire: int8 payload summed in int32; scales averaged (per-leaf scalar)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        n = denom or jax.lax.psum(1, axis_name)
        # unbiased average under per-participant scales ≈ sum(q_i * s_i)/n;
        # we approximate with mean scale (scales are near-equal across pods
        # for IID shards — the residual absorbs the difference next step)
        g_avg = q_sum.astype(jnp.float32) * (scale_sum / n) / n
        return g_avg, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in outs]), EFState(tdef.unflatten([o[1] for o in outs]))
