"""AdamW + global-norm clipping + LR schedules (pure pytree transforms; no
optax in the container). Moments are stored in fp32 regardless of param
dtype; the update is computed in fp32 and cast back.

The optimizer state is the canonical FaaSLight "optional collection": 2×
param bytes that no serving entry can ever reach — the Program Analyzer's
file-elimination stage drops it from serving artifacts (core.file_elim).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    m: Any  # fp32 pytree
    v: Any  # fp32 pytree


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # decay is skipped for 1-D leaves (norm scales / biases), per convention
    decay_min_ndim: int = 2


def init_adamw(params: Any) -> AdamWState:
    # m and v must be *distinct* buffers (donation forbids aliased inputs)
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def abstract_adamw(abstract_params: Any) -> AdamWState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: Optional[jax.Array] = None,
) -> tuple[Any, AdamWState]:
    """Returns (new_params, new_state). ``lr`` overrides cfg.lr (schedules)."""
    if cfg.clip_norm:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr_t = cfg.lr if lr is None else lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= cfg.decay_min_ndim:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr_t * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def warmup_cosine(base_lr: float, warmup: int, total: int, min_frac: float = 0.1) -> Callable:
    """step (int32 array) -> lr (fp32 array); jit-safe."""

    def sched(step):
        s = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(s / max(warmup, 1), 1.0)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * t)))
        return jnp.where(s < warmup, warm, cos)

    return sched
