"""Call-graph construction: jaxpr dataflow → parameter-leaf reachability.

The paper builds a *function-level call graph* with CHA-style static analysis
(§4.1 ③) and marks functions reachable from the entries as indispensable.
Our "functions" are parameter leaves, and the "call graph" is the jaxpr
dataflow graph of each entry point — traced abstractly via
``jax.make_jaxpr`` on ``ShapeDtypeStruct`` stand-ins, so the analysis never
allocates or computes (the same property the paper's static analysis has).

Where the paper's CHA is approximate for dynamic languages, jaxpr dataflow
is *exact at graph level*: a leaf is reachable from an entry iff its input
variable is live in the backward slice of the entry's outputs. The remaining
inaccuracy is *data-dependent* access (which expert / vocab row a request
uses) — handled, exactly as in the paper, by the on-demand backstop.

Backward liveness is computed recursively through sub-jaxprs (scan, cond,
while, pjit, remat, custom_{jvp,vjp}) so that e.g. a whisper decode entry
that never consumes encoder outputs leaves every encoder leaf dead even
though the leaves are formal inputs of the traced function.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

import jax
from jax.extend import core as jcore

from repro.utils.tree import flatten_with_paths, leaf_paths


# ---------------------------------------------------------------------------
# backward liveness over a (closed) jaxpr
# ---------------------------------------------------------------------------


def _as_jaxpr(x) -> jcore.Jaxpr | None:
    if isinstance(x, jcore.ClosedJaxpr):
        return x.jaxpr
    if isinstance(x, jcore.Jaxpr):
        return x
    return None


def _sub_jaxprs(eqn) -> list[tuple[jcore.Jaxpr, str]]:
    """(jaxpr, param_name) pairs contained in an eqn's params. Some
    primitives carry ClosedJaxpr (pjit, scan), others raw Jaxpr (remat2)."""
    out = []
    for k, v in eqn.params.items():
        j = _as_jaxpr(v)
        if j is not None:
            out.append((j, k))
        elif isinstance(v, (tuple, list)):
            for x in v:
                j = _as_jaxpr(x)
                if j is not None:
                    out.append((j, k))
    return out


def live_invars(jaxpr: jcore.Jaxpr, out_live: Sequence[bool]) -> list[bool]:
    """Which jaxpr.invars are live given liveness of jaxpr.outvars.

    Per-eqn precision: for higher-order primitives whose operands map 1:1 to
    a sub-jaxpr's invars (pjit, closed_call, remat, scan, custom_jvp/vjp) we
    recurse; for cond we map operands (after the predicate) into each branch
    and take the union; anything unknown is treated conservatively (all
    invars live if any outvar is).
    """
    live: set[int] = set()  # id(var) of live vars

    def mark(v) -> None:
        if not isinstance(v, jcore.Literal):
            live.add(id(v))

    def is_live(v) -> bool:
        return isinstance(v, jcore.Literal) or id(v) in live

    for v, l in zip(jaxpr.outvars, out_live):
        if l:
            mark(v)

    for eqn in reversed(jaxpr.eqns):
        outs_live = [is_live(v) for v in eqn.outvars]
        if not any(outs_live):
            continue
        prim = eqn.primitive.name
        handled = False
        if prim in ("pjit", "closed_call", "remat2", "checkpoint", "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr"):
            subs = _sub_jaxprs(eqn)
            if len(subs) == 1:
                sub = subs[0][0]
                if len(sub.invars) == len(eqn.invars) and len(sub.outvars) == len(eqn.outvars):
                    sub_live = live_invars(sub, outs_live)
                    for v, l in zip(eqn.invars, sub_live):
                        if l:
                            mark(v)
                    handled = True
        elif prim == "scan":
            sub = eqn.params["jaxpr"].jaxpr
            n_carry = eqn.params["num_carry"]
            # outvars = [carry..., ys...]; sub.outvars = [carry..., y_slices...]
            # A live carry-out at step T implies the carry chain is live at
            # every step, which in turn can consume any invar — iterate to a
            # fixed point over carry liveness.
            n_c = n_carry
            num_consts = eqn.params.get("num_consts", 0)
            carry_live = list(outs_live[:n_c])
            ys_live = outs_live[n_c:]
            # eqn.invars = [consts..., carry_init..., xs...] maps 1:1 to
            # sub.invars; carry positions are [num_consts, num_consts + n_c).
            for _ in range(n_c + 1):
                sub_out_live = list(carry_live) + list(ys_live)
                sub_in_live = live_invars(sub, sub_out_live)
                new_carry_live = [
                    carry_live[i] or sub_in_live[num_consts + i] for i in range(n_c)
                ]
                if new_carry_live == carry_live:
                    break
                carry_live = new_carry_live
            sub_out_live = list(carry_live) + list(ys_live)
            sub_in_live = live_invars(sub, sub_out_live)
            for v, l in zip(eqn.invars, sub_in_live):
                if l:
                    mark(v)
            handled = True
        elif prim == "cond":
            branches = eqn.params["branches"]
            ops = eqn.invars[1:]  # invars = [index, *operands]
            any_live = [False] * len(ops)
            for br in branches:
                sub_live = live_invars(br.jaxpr, outs_live)
                for i, l in enumerate(sub_live):
                    any_live[i] = any_live[i] or l
            mark(eqn.invars[0])
            for v, l in zip(ops, any_live):
                if l:
                    mark(v)
            handled = True
        elif prim == "while":
            # conservative: everything feeding a live while is live
            pass
        if not handled:
            for v in eqn.invars:
                mark(v)

    return [is_live(v) for v in jaxpr.invars]


# ---------------------------------------------------------------------------
# entry tracing → per-leaf reachability
# ---------------------------------------------------------------------------


@dataclass
class ReachabilityReport:
    """The FaaSLight call-graph result for one application.

    ``reachable[path]`` is the set of entry names whose backward slice
    contains the leaf; leaves with an empty set are *statically optional*
    (the paper's unreachable functions).
    """

    entry_names: list[str]
    reachable: dict[str, set] = field(default_factory=dict)
    n_eqns: dict[str, int] = field(default_factory=dict)

    @property
    def indispensable(self) -> set:
        return {p for p, s in self.reachable.items() if s}

    @property
    def statically_optional(self) -> set:
        return {p for p, s in self.reachable.items() if not s}

    def reaching(self, path: str) -> set:
        return self.reachable.get(path, set())


def trace_entry(fn: Callable, params_abstract: Any, args: tuple) -> jcore.ClosedJaxpr:
    """Abstractly trace fn(params, *args); no allocation, no FLOPs."""
    return jax.make_jaxpr(fn)(params_abstract, *args)


def entry_param_liveness(fn: Callable, params_abstract: Any, args: tuple) -> tuple[dict[str, bool], int]:
    """dotted-path -> is-live for one entry, plus eqn count (graph size)."""
    closed = trace_entry(fn, params_abstract, args)
    jaxpr = closed.jaxpr
    out_live = [True] * len(jaxpr.outvars)
    in_live = live_invars(jaxpr, out_live)

    # params are the first argument: the first len(param_leaves) flattened
    # invars correspond to the param tree leaves in flatten order.
    paths = leaf_paths(params_abstract)
    n = len(paths)
    liveness = dict(zip(paths, in_live[:n]))
    return liveness, len(jaxpr.eqns)


def build_reachability(entries: Iterable, params_abstract: Any) -> ReachabilityReport:
    """The Program Analyzer's ③ Optional Function Generation step: union of
    per-entry backward slices over all registered entries."""
    paths = leaf_paths(params_abstract)
    report = ReachabilityReport(entry_names=[], reachable={p: set() for p in paths})
    for ep in entries:
        liveness, n_eqns = entry_param_liveness(ep.fn, params_abstract, ep.args)
        report.entry_names.append(ep.name)
        report.n_eqns[ep.name] = n_eqns
        for p, l in liveness.items():
            if l:
                report.reachable[p].add(ep.name)
    return report
