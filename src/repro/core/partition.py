"""③ Optional Function Generation — the tier-0 / tier-1 split.

Combines the exact graph-level reachability (param_graph) with the model's
access annotations (ParamSpec.access) and the deployment profile into a
per-leaf ``TierDecision`` (DESIGN.md §4). The strategy mirrors §4 of the
paper exactly:

  * *aggressive identification*: any leaf whose bytes can be deferred is
    deferred — unreachable leaves, modal leaves outside the served
    modalities, routed expert tables, cold vocab row-groups;
  * *conservative backstop*: nothing is deleted — every tier-1 unit lives in
    the compressed optional store and is faulted in on first use, so a
    misprediction costs one fetch, never a crash.

Granularity (the paper's function-level unit): whole leaves for dense /
modal leaves; per-expert slices for ``routed`` tables; row-groups for
``rows:N`` tables. The paper's "don't rewrite a nested function whose parent
is already optional" dedup appears here as: units are defined on the leaf
level only — a leaf is exactly one unit set, never nested.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.entrypoints import DeploymentProfile
from repro.core.param_graph import ReachabilityReport
from repro.utils.tree import flatten_with_paths


@dataclass(frozen=True)
class Unit:
    """One on-demand loadable unit of a tier-1 leaf.

    ``sel`` is an integer index prefix into the leaf (e.g. ``(layer,
    expert)`` for a scan-stacked expert table, ``(expert,)`` unstacked);
    ``rows`` is a half-open row range on the axis after the prefix.
    ``nbytes`` is the raw (uncompressed) device cost of the unit — the
    quantity the residency budget charges/credits (DESIGN.md §8).
    """

    key: str          # "<path>" | "<path>#l<i>e<j>" | "<path>#rg<i>"
    path: str
    sel: tuple = ()
    rows: Optional[tuple] = None  # (row_start, row_end)
    nbytes: int = 0


@dataclass(frozen=True)
class TierDecision:
    path: str
    tier: int  # 0 = resident at cold start, 1 = on-demand
    granularity: str  # "leaf" | "expert" | "rows"
    reason: str
    nbytes: int
    units: tuple = ()  # tier-1 only
    resident_units: tuple = ()  # tier-1 units preloaded at cold start (hot set)


@dataclass
class TierPlan:
    decisions: dict  # path -> TierDecision
    profile: DeploymentProfile
    entry_names: list

    # -- summary ------------------------------------------------------------
    @property
    def tier0_bytes(self) -> int:
        return sum(d.nbytes for d in self.decisions.values() if d.tier == 0)

    @property
    def tier1_bytes(self) -> int:
        return sum(d.nbytes for d in self.decisions.values() if d.tier == 1)

    @property
    def total_bytes(self) -> int:
        return self.tier0_bytes + self.tier1_bytes

    @property
    def cold_resident_bytes(self) -> int:
        """Bytes uploaded at cold start: tier-0 + preloaded hot units."""
        total = self.tier0_bytes
        for d in self.decisions.values():
            if d.tier == 1 and d.units:
                per_unit = d.nbytes / len(d.units)
                total += int(per_unit * len(d.resident_units))
        return total

    @property
    def tier0_fraction(self) -> float:
        t = self.total_bytes
        return self.tier0_bytes / t if t else 1.0

    def units_for(self, path: str) -> tuple:
        return self.decisions[path].units

    def all_tier1_units(self) -> list[Unit]:
        out = []
        for d in self.decisions.values():
            out.extend(d.units)
        return out

    def summary(self) -> dict:
        n_t1 = sum(1 for d in self.decisions.values() if d.tier == 1)
        return {
            "profile": self.profile.name,
            "leaves": len(self.decisions),
            "tier1_leaves": n_t1,
            "tier0_bytes": self.tier0_bytes,
            "tier1_bytes": self.tier1_bytes,
            "cold_resident_bytes": self.cold_resident_bytes,
            "tier0_fraction": self.tier0_fraction,
            "units": len(self.all_tier1_units()),
        }


def _leaf_nbytes(leaf: Any) -> int:
    return int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize if leaf.shape else np.dtype(leaf.dtype).itemsize


def _expert_units(path: str, shape: tuple, expert_axis: int, itemsize: int) -> tuple:
    """Per-expert units; for scan-stacked tables (axes = ("layers",
    "experts", …)) the unit is one (layer, expert) slice — the finest
    granularity a request's routing decision selects."""
    n_exp = shape[expert_axis]
    if expert_axis == 0:
        slice_bytes = int(np.prod(shape[1:])) * itemsize
        return tuple(
            Unit(f"{path}#e{e}", path, sel=(e,), nbytes=slice_bytes)
            for e in range(n_exp)
        )
    n_layers = shape[0]
    slice_bytes = int(np.prod(shape[2:])) * itemsize
    return tuple(
        Unit(f"{path}#l{l}e{e}", path, sel=(l, e), nbytes=slice_bytes)
        for l in range(n_layers)
        for e in range(n_exp)
    )


def _row_units(path: str, n_rows: int, group: int, row_nbytes: int) -> tuple:
    n_groups = math.ceil(n_rows / group)
    return tuple(
        Unit(
            f"{path}#rg{g}", path,
            rows=(g * group, min((g + 1) * group, n_rows)),
            nbytes=(min((g + 1) * group, n_rows) - g * group) * row_nbytes,
        )
        for g in range(n_groups)
    )


def build_tier_plan(
    abstract_params: Any,
    access: dict,
    reach: ReachabilityReport,
    profile: DeploymentProfile,
    *,
    axes: Optional[dict] = None,  # path -> logical axes tuple (for expert-axis lookup)
    hot_units_stats: Optional[dict] = None,  # key -> hotness weight (offline stats)
) -> TierPlan:
    """The classification pass. ``access`` is path -> ParamSpec.access."""
    axes = axes or {}
    decisions: dict[str, TierDecision] = {}
    served = set(reach.entry_names)

    for path, leaf in flatten_with_paths(abstract_params):
        nbytes = _leaf_nbytes(leaf)
        acc = access.get(path, "dense")
        reaching = reach.reaching(path) & served

        # 1. unreachable from every served entry — statically optional
        if not reaching:
            decisions[path] = TierDecision(
                path, 1, "leaf",
                "unreachable from served entries (static)", nbytes,
                units=(Unit(path, path, nbytes=nbytes),),
            )
            continue

        # 2. small leaves always resident (norms/biases — the paper's
        #    "magic functions": cheap, ubiquitous, never worth separating)
        if nbytes < profile.min_tier1_bytes:
            decisions[path] = TierDecision(path, 0, "leaf", "small leaf", nbytes)
            continue

        # 3. modal leaves: resident only if the modality is served hot
        if acc.startswith("modal:"):
            modality = acc.split(":", 1)[1]
            if modality in profile.modalities:
                decisions[path] = TierDecision(path, 0, "leaf", f"modal:{modality} served", nbytes)
            else:
                decisions[path] = TierDecision(
                    path, 1, "leaf", f"modal:{modality} not in profile", nbytes,
                    units=(Unit(path, path, nbytes=nbytes),),
                )
            continue

        # 4. routed expert tables: per-(layer,)expert units, stats-selected
        #    residents (``resident_experts`` is *per layer*)
        if acc == "routed":
            leaf_axes = axes.get(path, ())
            expert_axis = leaf_axes.index("experts") if "experts" in leaf_axes else 0
            n_exp = leaf.shape[expert_axis]
            if profile.resident_experts < 0:
                decisions[path] = TierDecision(path, 0, "expert", "baseline: all experts resident", nbytes)
                continue
            units = _expert_units(path, leaf.shape, expert_axis, np.dtype(leaf.dtype).itemsize)
            n_res = min(profile.resident_experts, n_exp)
            # group units by layer prefix so each layer keeps n_res residents
            by_layer: dict = {}
            for u in units:
                by_layer.setdefault(u.sel[:-1], []).append(u)
            resident = []
            for layer_units in by_layer.values():
                if hot_units_stats:
                    layer_units = sorted(layer_units, key=lambda u: -hot_units_stats.get(u.key, 0.0))
                resident.extend(u.key for u in layer_units[:n_res])
            decisions[path] = TierDecision(
                path, 1, "expert", "routed expert table", nbytes,
                units=units, resident_units=tuple(resident),
            )
            continue

        # 5. row-indexed tables (embeddings): row-group units, hot fraction
        if acc.startswith("rows:"):
            n_rows = leaf.shape[int(acc.split(":")[1])]
            if profile.hot_vocab_fraction >= 1.0:
                decisions[path] = TierDecision(path, 0, "rows", "baseline: all rows resident", nbytes)
                continue
            units = _row_units(path, n_rows, profile.vocab_row_group, nbytes // n_rows)
            n_res = int(math.ceil(len(units) * profile.hot_vocab_fraction))
            if hot_units_stats:
                ranked = sorted(units, key=lambda u: -hot_units_stats.get(u.key, 0.0))
                resident = tuple(u.key for u in ranked[:n_res])
            else:
                resident = tuple(u.key for u in units[:n_res])
            decisions[path] = TierDecision(
                path, 1, "rows", "row-indexed table", nbytes,
                units=units, resident_units=resident,
            )
            continue

        # 6. densely consumed by a served entry — indispensable
        decisions[path] = TierDecision(path, 0, "leaf", f"dense, reached by {sorted(reaching)[:2]}", nbytes)

    return TierPlan(decisions=decisions, profile=profile, entry_names=list(reach.entry_names))
