"""④ The "lightweight file" — compressed key-value store for tier-1 units.

The paper separates optional functions into one compressed key-value blob
(~5000 functions ≈ 1 MB with gzip) shipped inside the deployment package;
``rewrite_template`` reads it on first miss. The analogue here is a single
``optional.blob`` file of concatenated zlib frames plus a JSON manifest
mapping unit keys to (offset, csize, rsize, shape, dtype, codec).

Design points carried over from the paper:
  * one global file, not one file per unit — a single open+seek per miss;
  * compression is per-unit so a miss decompresses only its own bytes;
  * the store is immutable after build (writes go through a temp+rename so
    a crashed build never corrupts a serveable artifact).

Beyond-paper (DESIGN.md §17):
  * bf16 weight entries are byte-planed (high/low byte planes stored
    separately) before compression — exponent bytes compress far better
    than interleaved high/low pairs, typically 1.3-2× better ratios on
    real weight tensors at negligible cost;
  * ``add_raw`` copies a compressed frame verbatim between stores, so
    compaction (``core/retier.py``) never pays decode+recompress for a
    unit it merely moves — its cost approaches pure sequential IO;
  * ``read_raw_many`` coalesces manifest-adjacent frames into single
    vectored preads (gap-bounded), so a co-access-ordered blob warms a
    whole cluster with one read;
  * every IO/decode failure is a typed ``StoreError`` naming the unit
    key — a torn frame, a corrupt zlib stream, or a blob/manifest skew
    can never surface as garbage bytes in a served tensor;
  * manifest v2 records the blob's committed length (+ crc32) so a crash
    between the blob rename and the manifest rename — the writer's two
    commit points — is detected at the next open, not at first read.
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

MAGIC = b"FLT1"
MANIFEST_VERSION = 2
_CODECS = ("raw", "zlib", "zlib-bp")  # bp = byte-planed

# default max gap (bytes) between two manifest frames that one vectored
# pread may still bridge: one page — reading a page-sized hole is cheaper
# than a second syscall + seek, and anything already adjacent after a
# co-access compaction coalesces for free. 0 disables coalescing.
COALESCE_GAP = 4096


class StoreError(Exception):
    """Base for every optional-store integrity failure. Always names the
    store path and, where one is involved, the unit key — the serving
    layer's contract is typed failure, never silently-garbage bytes."""

    def __init__(self, msg: str, *, key: Optional[str] = None,
                 path: Optional[str] = None):
        self.key = key
        self.path = path
        where = f" (unit {key!r})" if key else ""
        src = f" [{path}]" if path else ""
        super().__init__(f"{msg}{where}{src}")


class TornFrameError(StoreError):
    """A frame read came back short: the blob ends (or the manifest points)
    before ``offset + csize`` — a truncated or torn write."""


class CorruptFrameError(StoreError):
    """A frame's bytes don't decode: corrupt zlib stream, or the decoded
    size disagrees with the manifest's ``rsize``."""


class StoreSkewError(StoreError):
    """The blob and the manifest disagree (length/checksum): a crash landed
    between the writer's two commit renames, or the files were mixed from
    different builds."""


@dataclass
class ReadStats:
    """Per-call (or cumulative) vectored-read accounting: how many preads
    were issued for how many frames, and how many payload bytes arrived
    through multi-frame (coalesced) reads vs. were over-read as gap."""

    preads: int = 0           # pread syscalls issued
    frames: int = 0           # manifest frames delivered
    coalesced_bytes: int = 0  # payload bytes delivered by multi-frame preads
    gap_bytes: int = 0        # interstitial bytes read and discarded

    def add(self, other: "ReadStats") -> None:
        self.preads += other.preads
        self.frames += other.frames
        self.coalesced_bytes += other.coalesced_bytes
        self.gap_bytes += other.gap_bytes


def _encode(arr: np.ndarray, level: int) -> tuple[bytes, str]:
    raw = np.ascontiguousarray(arr).tobytes()
    if level <= 0:
        return raw, "raw"
    if arr.dtype.itemsize == 2:
        # byte-plane 2-byte dtypes (bf16/f16/i16): plane of high bytes then
        # low bytes — homogeneous exponent bytes compress much better.
        b = np.frombuffer(raw, np.uint8).reshape(-1, 2)
        planed = np.concatenate([b[:, 1], b[:, 0]]).tobytes()
        return zlib.compress(planed, level), "zlib-bp"
    return zlib.compress(raw, level), "zlib"


def _decode(buf: bytes, codec: str, shape: tuple, dtype: str) -> np.ndarray:
    dt = np.dtype(dtype)
    if codec == "raw":
        raw = buf
    elif codec == "zlib":
        raw = zlib.decompress(buf)
    elif codec == "zlib-bp":
        planed = np.frombuffer(zlib.decompress(buf), np.uint8)
        n = planed.size // 2
        b = np.empty((n, 2), np.uint8)
        b[:, 1] = planed[:n]
        b[:, 0] = planed[n:]
        raw = b.tobytes()
    else:
        raise ValueError(f"unknown codec {codec!r}")
    return np.frombuffer(raw, dt).reshape(shape).copy()


# numpy has no native bfloat16; store via ml_dtypes (jax dependency).
def _np_dtype(dtype_str: str) -> np.dtype:
    try:
        return np.dtype(dtype_str)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, dtype_str))


def _dtype_str(dt) -> str:
    return np.dtype(dt).name


@dataclass
class StoreEntry:
    offset: int
    csize: int
    rsize: int
    shape: tuple
    dtype: str
    codec: str


class OptionalStoreWriter:
    """Streaming writer: units are appended one at a time so building the
    store never holds more than one unit in memory.

    ``add`` encodes a host array; ``add_raw`` copies an already-compressed
    frame verbatim from another store (the compaction fast path, DESIGN.md
    §17.1 — the frame is *moved*, never decoded). ``layout`` is recorded
    in the manifest so a reader can tell a co-access-ordered blob from a
    build-order one.

    Commit order: blob rename first, then manifest rename. The window
    between the two is crash-detectable, not crash-safe — the manifest
    records the blob's committed length and crc32, and ``OptionalStore``
    refuses to open a store whose blob length disagrees with its manifest
    (``StoreSkewError``; tests/test_commit_crash.py).
    """

    def __init__(self, path: str, *, level: int = 6, layout: Optional[dict] = None):
        self.path = path
        self.level = level
        self.layout = dict(layout) if layout else {"source": "build-order"}
        self.manifest: Optional[dict] = None  # set by close(); public result
        self._tmp = path + ".partial"
        self._f = open(self._tmp, "wb")
        self._f.write(MAGIC)
        self._offset = len(MAGIC)
        self._crc = zlib.crc32(MAGIC)
        self._manifest: dict[str, dict] = {}

    def _append(self, key: str, buf: bytes, *, rsize: int, shape, dtype: str,
                codec: str) -> None:
        if key in self._manifest:
            raise KeyError(f"duplicate unit key {key!r}")
        self._f.write(buf)
        self._crc = zlib.crc32(buf, self._crc)
        self._manifest[key] = dict(
            offset=self._offset,
            csize=len(buf),
            rsize=rsize,
            shape=list(shape),
            dtype=dtype,
            codec=codec,
        )
        self._offset += len(buf)

    def add(self, key: str, arr: np.ndarray) -> None:
        buf, codec = _encode(arr, self.level)
        self._append(key, buf, rsize=arr.nbytes, shape=arr.shape,
                     dtype=_dtype_str(arr.dtype), codec=codec)

    def add_raw(self, key: str, buf: bytes, entry: StoreEntry) -> None:
        """Append one compressed frame verbatim (no decode, no recompress):
        ``buf`` is the exact frame bytes read from a source store and
        ``entry`` that store's manifest entry for it. The new manifest
        entry keeps csize/rsize/shape/dtype/codec and gets this blob's
        offset — byte-identical frames, new layout (the compaction copy
        rule, DESIGN.md §17.1)."""
        if len(buf) != entry.csize:
            raise TornFrameError(
                f"raw frame is {len(buf)} bytes, manifest says {entry.csize}",
                key=key, path=self.path)
        self._append(key, buf, rsize=entry.rsize, shape=entry.shape,
                     dtype=entry.dtype, codec=entry.codec)

    def close(self) -> dict:
        self._f.close()
        os.replace(self._tmp, self.path)  # commit 1: blob visible
        man_path = self.path + ".manifest.json"
        tmp = man_path + ".partial"
        doc = {
            "version": MANIFEST_VERSION,
            "blob_len": self._offset,
            "blob_crc32": self._crc & 0xFFFFFFFF,
            "layout": self.layout,
            "entries": self._manifest,
        }
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, man_path)  # commit 2: manifest names the new blob
        self.manifest = self._manifest
        return self.manifest

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if exc[0] is None:
            # propagate the close-result onto the public field so callers
            # (write_store) never reach into ``_manifest``
            self.close()
        else:
            self._f.close()
            if os.path.exists(self._tmp):
                os.remove(self._tmp)


class OptionalStore:
    """Read side — opened once at cold start; ``fetch`` per miss.

    Reads are thread-safe: the request path (synchronous fault-in) and the
    prefetcher's reader thread (DESIGN.md §8) share one handle, so byte
    reads go through ``os.pread`` (positioned, no shared seek cursor) with
    a locked seek+read fallback for platforms without ``pread``.

    Integrity (DESIGN.md §17.4): a v2 manifest records the committed blob
    length — a mismatch at open raises ``StoreSkewError`` (a crash between
    the writer's blob and manifest renames, or mixed files). Every frame
    read is length-checked (``TornFrameError``) and every decode failure
    is a ``CorruptFrameError`` naming the unit key.
    """

    def __init__(self, path: str):
        self.path = path
        try:
            with open(path + ".manifest.json") as f:
                man = json.load(f)
        except (json.JSONDecodeError, FileNotFoundError) as e:
            raise StoreSkewError(
                f"manifest unreadable: {e}", path=path) from e
        self.version = man.get("version", 1)
        if self.version not in (1, MANIFEST_VERSION):
            raise StoreError(
                f"unsupported manifest version {self.version!r}", path=path)
        self.layout: dict = man.get("layout") or {"source": "build-order"}
        self.blob_len: Optional[int] = man.get("blob_len")
        self.blob_crc32: Optional[int] = man.get("blob_crc32")
        self.entries: dict[str, StoreEntry] = {
            k: StoreEntry(
                offset=v["offset"], csize=v["csize"], rsize=v["rsize"],
                shape=tuple(v["shape"]), dtype=v["dtype"], codec=v["codec"],
            )
            for k, v in man["entries"].items()
        }
        self._f = open(path, "rb")
        self._read_lock = threading.Lock()
        self._pread = getattr(os, "pread", None)
        self.read_stats = ReadStats()  # cumulative, updated under _read_lock
        if self.blob_len is not None:
            actual = os.fstat(self._f.fileno()).st_size
            if actual != self.blob_len:
                self._f.close()
                raise StoreSkewError(
                    f"blob is {actual} bytes but the manifest committed "
                    f"{self.blob_len} — blob and manifest are from different "
                    f"builds (crash between the two commit renames?)",
                    path=path)
        if self._f.read(len(MAGIC)) != MAGIC:
            self._f.close()
            raise StoreError("bad magic — not an optional store", path=path)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    def keys(self) -> Iterable[str]:
        return self.entries.keys()

    @property
    def compressed_bytes(self) -> int:
        return sum(e.csize for e in self.entries.values())

    @property
    def raw_bytes(self) -> int:
        return sum(e.rsize for e in self.entries.values())

    def verify(self) -> None:
        """Full-blob crc32 check against the manifest (v2 only; an
        explicit integrity pass — too expensive for every open)."""
        if self.blob_crc32 is None:
            return
        crc = 0
        pos = 0
        while True:
            chunk = self._pread_span(pos, 1 << 20)
            if not chunk:
                break
            crc = zlib.crc32(chunk, crc)
            pos += len(chunk)
        if crc & 0xFFFFFFFF != self.blob_crc32:
            raise StoreSkewError(
                f"blob crc32 {crc & 0xFFFFFFFF:#x} != manifest "
                f"{self.blob_crc32:#x}", path=self.path)

    # -- positioned byte reads ------------------------------------------------
    def _pread_span(self, offset: int, size: int) -> bytes:
        """One positioned read of ``size`` bytes at ``offset`` (may come
        back short at EOF — callers length-check)."""
        if self._pread is not None:
            return self._pread(self._f.fileno(), size, offset)
        with self._read_lock:
            self._f.seek(offset)
            return self._f.read(size)

    def _count(self, preads: int, frames: int, coalesced: int, gap: int,
               out: Optional[ReadStats]) -> None:
        delta = ReadStats(preads, frames, coalesced, gap)
        with self._read_lock:
            self.read_stats.add(delta)
        if out is not None:
            out.add(delta)

    def read_raw(self, key: str, *, stats: Optional[ReadStats] = None) -> bytes:
        """Positioned read of one unit's compressed frame (thread-safe).
        Short reads — the blob ends before ``offset + csize`` — raise
        ``TornFrameError`` naming the unit, never return partial bytes."""
        e = self.entries[key]
        try:
            buf = self._pread_span(e.offset, e.csize)
        except OSError as err:
            raise TornFrameError(f"frame read failed: {err}",
                                 key=key, path=self.path) from err
        if len(buf) != e.csize:
            raise TornFrameError(
                f"frame at offset {e.offset} is torn: wanted {e.csize} "
                f"bytes, blob yielded {len(buf)}", key=key, path=self.path)
        self._count(1, 1, 0, 0, stats)
        return buf

    def read_raw_many(
        self,
        keys: Iterable[str],
        *,
        gap_threshold: int = COALESCE_GAP,
        stats: Optional[ReadStats] = None,
    ) -> dict[str, bytes]:
        """Vectored read of many frames: manifest-adjacent frames (gap
        between consecutive frames ≤ ``gap_threshold`` bytes) are fetched
        with ONE pread spanning them, then sliced apart — byte-identical
        to per-key ``read_raw`` (tests/test_store_faults.py), just fewer
        syscalls/seeks. ``gap_threshold=0`` disables coalescing entirely
        (one pread per frame — the degenerate contract the tests pin).
        Duplicate keys are deduped; key order is irrelevant (frames are
        read in offset order). Torn frames raise ``TornFrameError`` naming
        the first affected unit."""
        ks = list(dict.fromkeys(keys))
        if not ks:
            return {}
        ents = sorted(((k, self.entries[k]) for k in ks),
                      key=lambda ke: ke[1].offset)
        # greedy run grouping over the offset-sorted frames
        runs: list[list[tuple[str, StoreEntry]]] = [[ents[0]]]
        for k, e in ents[1:]:
            prev = runs[-1][-1][1]
            gap = e.offset - (prev.offset + prev.csize)
            if gap_threshold > 0 and 0 <= gap <= gap_threshold:
                runs[-1].append((k, e))
            else:
                runs.append([(k, e)])
        out: dict[str, bytes] = {}
        preads = frames = coalesced = gap_bytes = 0
        for run in runs:
            start = run[0][1].offset
            end = run[-1][1].offset + run[-1][1].csize
            try:
                span = self._pread_span(start, end - start)
            except OSError as err:
                raise TornFrameError(f"frame read failed: {err}",
                                     key=run[0][0], path=self.path) from err
            preads += 1
            payload = 0
            for k, e in run:
                rel = e.offset - start
                buf = span[rel:rel + e.csize]
                if len(buf) != e.csize:
                    raise TornFrameError(
                        f"frame at offset {e.offset} is torn: wanted "
                        f"{e.csize} bytes, blob yielded {len(buf)}",
                        key=k, path=self.path)
                out[k] = buf
                payload += e.csize
            frames += len(run)
            if len(run) > 1:
                coalesced += payload
                gap_bytes += (end - start) - payload
        self._count(preads, frames, coalesced, gap_bytes,
                    stats)
        return out

    def decode(self, key: str, buf: bytes) -> np.ndarray:
        """Decompress one unit's frame (CPU-bound; safe off the lock).
        Corruption — an undecodable zlib stream, or decoded bytes that
        disagree with the manifest's rsize/shape — raises
        ``CorruptFrameError`` naming the unit, never returns garbage."""
        e = self.entries[key]
        try:
            arr = _decode(buf, e.codec, e.shape, _np_dtype(e.dtype))
        except (zlib.error, ValueError) as err:
            raise CorruptFrameError(
                f"frame does not decode ({err})", key=key, path=self.path
            ) from err
        if arr.nbytes != e.rsize:
            raise CorruptFrameError(
                f"decoded {arr.nbytes} bytes, manifest says {e.rsize}",
                key=key, path=self.path)
        return arr

    def fetch(self, key: str) -> np.ndarray:
        return self.decode(key, self.read_raw(key))

    def unit_nbytes(self, key: str) -> int:
        return self.entries[key].rsize

    def fetch_many(
        self,
        keys: Iterable[str],
        *,
        gap_threshold: int = COALESCE_GAP,
        stats: Optional[ReadStats] = None,
    ) -> dict[str, np.ndarray]:
        """Vectored fetch: one read pass over the file region (coalesced
        preads via ``read_raw_many``), then per-frame decode. Returned in
        offset order, as before."""
        bufs = self.read_raw_many(keys, gap_threshold=gap_threshold,
                                  stats=stats)
        ks = sorted(bufs, key=lambda k: self.entries[k].offset)
        return {k: self.decode(k, bufs[k]) for k in ks}

    def close(self) -> None:
        self._f.close()


def write_store(path: str, units: Iterable[tuple[str, np.ndarray]], *,
                level: int = 6, layout: Optional[dict] = None) -> dict:
    with OptionalStoreWriter(path, level=level, layout=layout) as w:
        for key, arr in units:
            w.add(key, arr)
    # __exit__ ran close(); its result lives on the public field
    assert w.manifest is not None
    return w.manifest
