"""④ The "lightweight file" — compressed key-value store for tier-1 units.

The paper separates optional functions into one compressed key-value blob
(~5000 functions ≈ 1 MB with gzip) shipped inside the deployment package;
``rewrite_template`` reads it on first miss. The analogue here is a single
``optional.blob`` file of concatenated zlib frames plus a JSON manifest
mapping unit keys to (offset, csize, rsize, shape, dtype, codec).

Design points carried over from the paper:
  * one global file, not one file per unit — a single open+seek per miss;
  * compression is per-unit so a miss decompresses only its own bytes;
  * the store is immutable after build (writes go through a temp+rename so
    a crashed build never corrupts a serveable artifact).

Beyond-paper: bf16 weight entries are byte-planed (high/low byte planes
stored separately) before compression — exponent bytes compress far better
than interleaved high/low pairs, typically 1.3-2× better ratios on real
weight tensors at negligible cost.
"""

from __future__ import annotations

import json
import os
import struct
import threading
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Optional

import numpy as np

MAGIC = b"FLT1"
_CODECS = ("raw", "zlib", "zlib-bp")  # bp = byte-planed


def _encode(arr: np.ndarray, level: int) -> tuple[bytes, str]:
    raw = np.ascontiguousarray(arr).tobytes()
    if level <= 0:
        return raw, "raw"
    if arr.dtype.itemsize == 2:
        # byte-plane 2-byte dtypes (bf16/f16/i16): plane of high bytes then
        # low bytes — homogeneous exponent bytes compress much better.
        b = np.frombuffer(raw, np.uint8).reshape(-1, 2)
        planed = np.concatenate([b[:, 1], b[:, 0]]).tobytes()
        return zlib.compress(planed, level), "zlib-bp"
    return zlib.compress(raw, level), "zlib"


def _decode(buf: bytes, codec: str, shape: tuple, dtype: str) -> np.ndarray:
    dt = np.dtype(dtype)
    if codec == "raw":
        raw = buf
    elif codec == "zlib":
        raw = zlib.decompress(buf)
    elif codec == "zlib-bp":
        planed = np.frombuffer(zlib.decompress(buf), np.uint8)
        n = planed.size // 2
        b = np.empty((n, 2), np.uint8)
        b[:, 1] = planed[:n]
        b[:, 0] = planed[n:]
        raw = b.tobytes()
    else:
        raise ValueError(f"unknown codec {codec!r}")
    return np.frombuffer(raw, dt).reshape(shape).copy()


# numpy has no native bfloat16; store via ml_dtypes (jax dependency).
def _np_dtype(dtype_str: str) -> np.dtype:
    try:
        return np.dtype(dtype_str)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, dtype_str))


def _dtype_str(dt) -> str:
    return np.dtype(dt).name


@dataclass
class StoreEntry:
    offset: int
    csize: int
    rsize: int
    shape: tuple
    dtype: str
    codec: str


class OptionalStoreWriter:
    """Streaming writer: units are appended one at a time so building the
    store never holds more than one unit in memory."""

    def __init__(self, path: str, *, level: int = 6):
        self.path = path
        self.level = level
        self._tmp = path + ".partial"
        self._f = open(self._tmp, "wb")
        self._f.write(MAGIC)
        self._offset = len(MAGIC)
        self._manifest: dict[str, dict] = {}

    def add(self, key: str, arr: np.ndarray) -> None:
        if key in self._manifest:
            raise KeyError(f"duplicate unit key {key!r}")
        buf, codec = _encode(arr, self.level)
        self._f.write(buf)
        self._manifest[key] = dict(
            offset=self._offset,
            csize=len(buf),
            rsize=arr.nbytes,
            shape=list(arr.shape),
            dtype=_dtype_str(arr.dtype),
            codec=codec,
        )
        self._offset += len(buf)

    def close(self) -> dict:
        self._f.close()
        os.replace(self._tmp, self.path)  # atomic commit
        man_path = self.path + ".manifest.json"
        tmp = man_path + ".partial"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": self._manifest}, f)
        os.replace(tmp, man_path)
        return self._manifest

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if exc[0] is None:
            self.close()
        else:
            self._f.close()
            if os.path.exists(self._tmp):
                os.remove(self._tmp)


class OptionalStore:
    """Read side — opened once at cold start; ``fetch`` per miss.

    Reads are thread-safe: the request path (synchronous fault-in) and the
    prefetcher's reader thread (DESIGN.md §8) share one handle, so byte
    reads go through ``os.pread`` (positioned, no shared seek cursor) with
    a locked seek+read fallback for platforms without ``pread``.
    """

    def __init__(self, path: str):
        self.path = path
        with open(path + ".manifest.json") as f:
            man = json.load(f)
        self.entries: dict[str, StoreEntry] = {
            k: StoreEntry(
                offset=v["offset"], csize=v["csize"], rsize=v["rsize"],
                shape=tuple(v["shape"]), dtype=v["dtype"], codec=v["codec"],
            )
            for k, v in man["entries"].items()
        }
        self._f = open(path, "rb")
        self._read_lock = threading.Lock()
        self._pread = getattr(os, "pread", None)
        if self._f.read(len(MAGIC)) != MAGIC:
            raise ValueError(f"{path}: bad magic — not an optional store")

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    def keys(self) -> Iterable[str]:
        return self.entries.keys()

    @property
    def compressed_bytes(self) -> int:
        return sum(e.csize for e in self.entries.values())

    @property
    def raw_bytes(self) -> int:
        return sum(e.rsize for e in self.entries.values())

    def read_raw(self, key: str) -> bytes:
        """Positioned read of one unit's compressed frame (thread-safe)."""
        e = self.entries[key]
        if self._pread is not None:
            return self._pread(self._f.fileno(), e.csize, e.offset)
        with self._read_lock:
            self._f.seek(e.offset)
            return self._f.read(e.csize)

    def decode(self, key: str, buf: bytes) -> np.ndarray:
        """Decompress one unit's frame (CPU-bound; safe off the lock)."""
        e = self.entries[key]
        return _decode(buf, e.codec, e.shape, _np_dtype(e.dtype))

    def fetch(self, key: str) -> np.ndarray:
        return self.decode(key, self.read_raw(key))

    def unit_nbytes(self, key: str) -> int:
        return self.entries[key].rsize

    def fetch_many(self, keys: Iterable[str]) -> dict[str, np.ndarray]:
        # sort by offset: sequential reads, one pass over the file region
        ks = sorted(keys, key=lambda k: self.entries[k].offset)
        return {k: self.fetch(k) for k in ks}

    def close(self) -> None:
        self._f.close()


def write_store(path: str, units: Iterable[tuple[str, np.ndarray]], *, level: int = 6) -> dict:
    with OptionalStoreWriter(path, level=level) as w:
        for key, arr in units:
            w.add(key, arr)
    return w._manifest
