"""⑨ Fleet federation — cross-replica trace aggregation + learned
pre-warm (DESIGN.md §14).

A ``RetierDaemon`` (§12) adapts ONE replica from its own traffic, which
means N replicas behind a load balancer each pay the full exploration
cost of a workload shift: every replica must fault on the new hot set
before its own daemon learns it. The ``FleetController`` closes that gap
by federating what the replicas observe:

    replica daemons ──pull_window()──▶ windows of ONE sync cycle
        ──AccessTrace.merge_all (plain sum, commutative)──▶ combined
        ──history.merge(combined, decay)──▶ fleet history
        ──replan ONCE from the base plan──▶ fleet plan
        ──residency_overlay──▶ {tier-1 path: hot unit keys}
        ──apply_overlay + RetierDaemon.apply_plan──▶ every replica

so a shift ANY replica sees pre-warms ALL of them, and the per-replica
daemons' own safety machinery is unchanged: each replica re-proves the
tier-0 ⊇ entry-reachable invariant itself before mutating (§12.1 rule 1
— the controller is not trusted), promotions ride the prefetcher or a
between-batches synchronous preload, demotions respect pins.

Federation contract (DESIGN.md §14.1):

  * **order-independent**: the windows of one cycle are combined with an
    undecayed, commutative sum (``AccessTrace.merge_all``) BEFORE the
    single decayed fold into history — the fleet plan cannot depend on
    the order replicas are polled in;
  * **overlay, not plan**: what crosses the replica boundary is the
    residency overlay (plain ``{path: [unit key, ...]}``), applied to
    each replica's OWN plan via ``apply_overlay`` — tiers can never flip
    remotely, foreign unit keys are ignored, and the state serializes;
  * **failure-isolated**: a replica that fails a pull or rejects a push
    (invariant violation, I/O error) is recorded and skipped — the cycle
    completes for every other replica, and the failing replica's loader
    is untouched (``apply_plan`` checks before mutating);
  * **warm bootstrap**: ``snapshot()`` captures history + overlay as
    JSON; a late joiner restored from it applies the fleet plan with a
    SYNCHRONOUS preload at ``register()`` time — resident before it
    admits traffic, instead of re-faulting its way to the fleet's hot
    set.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

from repro.core.on_demand import AccessTrace
from repro.core import snapshot as server_snapshot_mod
from repro.core.retier import (
    apply_overlay,
    replan_from_trace,
    residency_overlay,
)


@dataclass
class FleetStats:
    """Controller lifetime accounting (printed by the launcher, asserted
    by tests/test_fleet.py and benchmarks/bench_rq10_fleet.py)."""

    syncs: int = 0              # sync() cycles run
    pulls: int = 0              # per-replica window pulls attempted
    pull_failures: int = 0      # pulls that raised (replica skipped)
    empty_windows: int = 0      # pulls that returned no new batches
    replans: int = 0            # cycles that produced a fresh fleet plan
    pushes: int = 0             # per-replica plan applications that stuck
    push_failures: int = 0      # rejected/failed applications (isolated)
    bootstraps: int = 0         # late joiners warm-started at register()
    bootstrap_failures: int = 0

    def to_dict(self) -> dict:
        return dict(self.__dict__)


class FleetController:
    """Federates N ``RetierDaemon``s into one learned hot set.

    The controller is *passive* like the daemons it drives: it owns no
    thread, and ``sync()`` is called from whatever loop coordinates the
    replicas (the ``--fleet`` launcher, a test, a cron). All controller
    state is behind one lock; every replica mutation goes through
    ``RetierDaemon.apply_plan``, which takes the daemon's own lock and
    re-proves the §12.1 invariant before touching the loader.

    The canonical fleet state is deliberately tiny and portable: the
    decayed fleet history (an ``AccessTrace``) plus the last residency
    overlay. ``snapshot()``/``restore()`` round-trip exactly that —
    byte-identically, by the §10 canonical-number rule — which is the
    whole warm-bootstrap story (§14.1).
    """

    SNAPSHOT_VERSION = 1

    def __init__(
        self,
        *,
        decay: float = 0.5,
        promote_min_faults: int = 1,
        max_promote_bytes: Optional[int] = None,
        sync_preload: bool = False,
    ):
        if not 0.0 <= decay <= 1.0:
            raise ValueError(f"decay must be in [0, 1], got {decay!r}")
        self.decay = decay
        self.promote_min_faults = promote_min_faults
        self.max_promote_bytes = max_promote_bytes
        # sync_preload=True makes every push load promotions synchronously
        # INSIDE sync() — between batches, off any request path — instead
        # of queueing prefetch hints. Deterministic residency after each
        # cycle, at the cost of sync() stalling on tier-1 reads; the mode
        # for coordinators that sync idle/between-phase replicas.
        self.sync_preload = sync_preload
        self.stats = FleetStats()
        self._lock = threading.Lock()
        self._replicas: dict[str, object] = {}  # name -> RetierDaemon
        self._history: Optional[AccessTrace] = None
        self._overlay: Optional[dict[str, list[str]]] = None
        # replan determinism: always from the FIRST registered replica's
        # plan + static analysis, with the controller's own last overlay
        # as the resident set (fault-admitted, touch-retained — see
        # ``sync``); never from any replica's drifting live plan
        self._base_plan = None
        self._reach = None
        self._min_budget: Optional[int] = None  # tightest replica budget seen
        # warm server snapshot (DESIGN.md §15.3) offered by a warmed
        # replica; restored onto late joiners at register() — the
        # bootstrap fast path that skips re-faulting the hot set
        self._server_snapshot: Optional[dict] = None
        self.last_errors: dict[str, str] = {}

    # -- membership --------------------------------------------------------------
    @property
    def replicas(self) -> list[str]:
        with self._lock:
            return sorted(self._replicas)

    def register(self, name: str, daemon, *, server_snapshot: Optional[dict] = None) -> bool:
        """Add a replica's daemon to the fleet. The first registration
        donates the base plan + reachability the controller replans from.

        Two warm-bootstrap paths run here, fast first (DESIGN.md §15.3
        then §14.1): a *server snapshot* (passed in, or previously
        ``offer_server_snapshot``-ed by a warmed replica) replays a donor
        replica's exact residency set + LRU order + predictor onto the
        joiner; then, if the fleet has learned an overlay, the fleet plan
        is applied with a synchronous preload. Returns True when either
        left the replica warm. A bootstrap failure is absorbed (recorded
        in ``stats``/``last_errors``) — the replica still joins, merely
        cold, exactly as if unfederated."""
        with self._lock:
            if name in self._replicas:
                raise ValueError(f"replica {name!r} already registered")
            self._replicas[name] = daemon
            if self._base_plan is None:
                self._base_plan = daemon.tiered.plan
                self._reach = daemon.reach
            b = daemon.tiered.residency.budget_bytes
            if b and (self._min_budget is None or b < self._min_budget):
                # the fleet plans for its tightest replica: an overlay the
                # smallest budget can't hold would LRU-churn that replica
                # instead of warming it
                self._min_budget = b
            warmed = False
            snap = server_snapshot if server_snapshot is not None else self._server_snapshot
            if snap is not None:
                try:
                    rep = server_snapshot_mod.restore(
                        daemon.tiered, snap,
                        prefetcher=getattr(daemon, "prefetcher", None),
                        artifact_dir=getattr(daemon, "artifact_dir", None),
                        strict=False,  # mismatched artifact → cold join, not a crash
                    )
                    if rep["restored"]:
                        self.stats.bootstraps += 1
                        warmed = True
                except Exception as e:
                    self.stats.bootstrap_failures += 1
                    self.last_errors[name] = repr(e)
            if self._overlay is None:
                return warmed
            try:
                plan = apply_overlay(daemon.tiered.plan, self._overlay)
                daemon.apply_plan(plan, trace=self._history, sync_preload=True)
                self.stats.bootstraps += 1
                return True
            except Exception as e:  # cold join is a degraded mode, not a crash
                self.stats.bootstrap_failures += 1
                self.last_errors[name] = repr(e)
                return warmed

    def offer_server_snapshot(self, snap: Optional[dict]) -> None:
        """Stash a warmed replica's server snapshot (``ColdStartServer.
        snapshot()``) for every future ``register()`` to restore from.
        ``None`` clears it. Version-checked on offer so a bad document
        fails loudly here, not inside some later join."""
        if snap is not None:
            version = snap.get("version")
            if version != server_snapshot_mod.SNAPSHOT_VERSION:
                raise ValueError(
                    f"unsupported server snapshot version {version!r} "
                    f"(expected {server_snapshot_mod.SNAPSHOT_VERSION})"
                )
        with self._lock:
            self._server_snapshot = snap

    def unregister(self, name: str) -> None:
        """Drop a replica (drained / crashed). Its contributions stay in
        the decayed history — evidence outlives membership."""
        with self._lock:
            self._replicas.pop(name, None)

    # -- one federation cycle ----------------------------------------------------
    def sync(self) -> dict:
        """Run one pull → merge → replan → push cycle; returns a summary.

        Never raises for per-replica trouble: a failing pull or push is
        recorded (``stats``, ``last_errors``, the summary's ``failed``
        map) and the cycle continues for the rest of the fleet."""
        with self._lock:
            self.stats.syncs += 1
            summary: dict = {
                "pulled": 0, "windows": 0, "replanned": False,
                "pushed": [], "bootstrapped": [], "failed": {},
                "promoted": 0, "demoted": 0,
            }
            if not self._replicas:
                return summary

            # 1. pull one window per replica (failure-isolated)
            windows = []
            for name, daemon in self._replicas.items():
                self.stats.pulls += 1
                summary["pulled"] += 1
                try:
                    w = daemon.pull_window()
                except Exception as e:
                    self.stats.pull_failures += 1
                    self.last_errors[name] = repr(e)
                    summary["failed"][name] = f"pull: {e!r}"
                    continue
                if w is None:
                    self.stats.empty_windows += 1
                else:
                    windows.append(w)
            summary["windows"] = len(windows)

            # 2. commutative combine, then ONE decayed fold (§14.1 rule 1)
            if windows:
                combined = AccessTrace.merge_all(windows)
                self._history = (
                    combined if self._history is None
                    else self._history.merge(combined, decay=self.decay)
                )

            # 3. replan ONCE against the fleet history — from the base plan
            # CARRYING the previous overlay. Replanning from the pristine
            # base would make residency require *ongoing faults*, and a
            # federated pre-warm exists precisely to stop units faulting:
            # warmed units would lose their (decayed, pruned) fault
            # evidence, fall out of the overlay, be demoted, refault, and
            # be re-admitted — a fleet-wide eviction/refault oscillation.
            # With the previous overlay as the replan's resident set, a
            # fault ADMITS a unit and decayed touches RETAIN it; it drops
            # out only once the fleet stops touching it (the same
            # semantics a local daemon gets by replanning from its live
            # plan). Promotions still never compound: retention requires
            # touches, which prune to zero a few decayed folds after the
            # workload moves on.
            if self._history is None or not self._history.batches:
                return summary
            replan_base = (
                self._base_plan if self._overlay is None
                else apply_overlay(self._base_plan, self._overlay)
            )
            new_plan, _report = replan_from_trace(
                replan_base,
                self._history,
                self._reach,
                promote_min_faults=self.promote_min_faults,
                max_promote_bytes=self.max_promote_bytes,
                promote_leaves=False,  # §12.1 rule 2: tier flips are local-only
            )
            self._overlay = self._trim_overlay(
                residency_overlay(new_plan), new_plan, self._history)
            self.stats.replans += 1
            summary["replanned"] = True

            # 4. push to every replica as an overlay on ITS plan
            for name, daemon in self._replicas.items():
                try:
                    plan = apply_overlay(daemon.tiered.plan, self._overlay)
                    res = daemon.apply_plan(plan, trace=self._history,
                                            sync_preload=self.sync_preload)
                except Exception as e:
                    self.stats.push_failures += 1
                    self.last_errors[name] = repr(e)
                    summary["failed"][name] = f"push: {e!r}"
                    continue
                self.stats.pushes += 1
                summary["pushed"].append(name)
                summary["promoted"] += res["promoted"]
                summary["demoted"] += res["demoted"]
            return summary

    def _trim_overlay(
        self, overlay: dict[str, list[str]], plan, history: AccessTrace
    ) -> dict[str, list[str]]:
        """Fit the overlay to the fleet's tightest replica budget, keeping
        the globally hottest units (by federated touch+fault heat). The
        replan promotes everything the history justifies; the budget is a
        per-replica property the replan can't see, so the cap is applied
        here — per-path order (replan's within-path ranking) is kept for
        whatever survives. No registered budget → nothing to trim."""
        cap = self._min_budget
        if not cap:
            return overlay
        sizes = {
            u.key: u.nbytes
            for dec in plan.decisions.values() if dec.tier == 1
            for u in dec.units
        }
        def heat(k: str) -> float:
            return history.touches.get(k, 0) + history.faults.get(k, 0)
        ranked = sorted(
            ((p, k) for p, ks in overlay.items() for k in ks),
            key=lambda pk: (-heat(pk[1]), pk[1]),  # deterministic tie-break
        )
        kept: set[str] = set()
        total = 0
        for _, k in ranked:
            nb = sizes.get(k, 0)
            if total + nb <= cap:
                kept.add(k)
                total += nb
        return {p: [k for k in ks if k in kept] for p, ks in overlay.items()}

    # -- warm bootstrap ----------------------------------------------------------
    def snapshot(self) -> dict:
        """The fleet's learned state as a plain-JSON dict: the decayed
        history (§10 canonical numbers — round-trips byte-identically)
        plus the last pushed overlay. No plans, no unit objects, no
        replica handles: a controller in another process can ``restore``
        this and warm-bootstrap replicas it has never met."""
        with self._lock:
            return {
                "version": self.SNAPSHOT_VERSION,
                "decay": self.decay,
                "promote_min_faults": self.promote_min_faults,
                "max_promote_bytes": self.max_promote_bytes,
                "sync_preload": self.sync_preload,
                "history": None if self._history is None else self._history.to_dict(),
                "overlay": None if self._overlay is None else {
                    p: list(ks) for p, ks in sorted(self._overlay.items())
                },
                # §15.3 fast path rides along; absent/None in older
                # documents, so v1 snapshots from before it still load
                "server_snapshot": self._server_snapshot,
            }

    @classmethod
    def restore(cls, snap: dict) -> "FleetController":
        """Rebuild a controller from ``snapshot()`` output. Replicas are
        NOT restored — they re-``register``, and any that join while the
        restored overlay is set get the §14.1 warm bootstrap."""
        version = snap.get("version")
        if version != cls.SNAPSHOT_VERSION:
            raise ValueError(
                f"unsupported fleet snapshot version {version!r} "
                f"(expected {cls.SNAPSHOT_VERSION})"
            )
        fc = cls(
            decay=snap["decay"],
            promote_min_faults=snap["promote_min_faults"],
            max_promote_bytes=snap["max_promote_bytes"],
            sync_preload=snap.get("sync_preload", False),
        )
        if snap.get("history") is not None:
            fc._history = AccessTrace.from_dict(snap["history"])
        if snap.get("overlay") is not None:
            fc._overlay = {p: list(ks) for p, ks in snap["overlay"].items()}
        fc._server_snapshot = snap.get("server_snapshot")
        return fc

    # -- introspection -----------------------------------------------------------
    @property
    def history(self) -> Optional[AccessTrace]:
        """The decayed federated history the last replan saw."""
        with self._lock:
            return self._history

    @property
    def overlay(self) -> Optional[dict[str, list[str]]]:
        """The last pushed residency overlay (a copy)."""
        with self._lock:
            if self._overlay is None:
                return None
            return {p: list(ks) for p, ks in self._overlay.items()}
