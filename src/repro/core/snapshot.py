"""⑩ Warm server snapshot/restore (DESIGN.md §15.3).

A warmed ``ColdStartServer`` embodies state that took real traffic to
learn: which tier-1 units are RESIDENT, in what LRU order, and what the
prefetch predictor knows about unit→unit transitions. A fresh replica
joining a scaled-out deployment re-pays all of that as request-path
faults. This module serializes exactly that state — small, plain JSON,
no tensor bytes — so a new replica can *restore to RESIDENT-warm before
admitting traffic*:

  * ``capture(tiered, ...)`` → dict with the residency set + logical LRU
    stamps, the predictor's ranked tables, and the artifact identity
    (a fingerprint of the artifact directory's file names/sizes and its
    JSON manifests);
  * ``restore(tiered, snap, ...)`` verifies the fingerprint (the
    compatibility rule: weights bytes come from the *artifact*, so a
    snapshot is only meaningful against the same artifact), re-faults
    the resident set oldest-first through the normal ``ensure`` path —
    budget, eviction, and any ``HostArbiter`` make-room charges all
    apply exactly as for organic traffic — then reinstates the donor's
    LRU stamps, and arms the prefetcher's predictor.

The snapshot deliberately carries no device bytes and no plan objects:
restore is a *replay* against the restoring replica's own artifact and
budget, so a tighter replica simply keeps the hottest (newest-stamped)
suffix of the donor's resident set and a foreign unit key is skipped,
never an error. Wired into ``cold_start(restore_from=...)``, the
launcher's ``--snapshot-out``/``--restore-from``, and
``FleetController.register`` (the bootstrap fast path).
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Optional

from repro.core.prefetch import TransitionPredictor

SNAPSHOT_VERSION = 1


def artifact_fingerprint(artifact_dir: str) -> str:
    """Identity of an artifact directory: sha256 over every file's
    relative path and size, plus the *content* of JSON manifests (small,
    and where layout-changing rewrites announce themselves). Two
    directories that disagree here hold different artifacts; a snapshot
    must not cross that line (DESIGN.md §15.3)."""
    h = hashlib.sha256()
    for root, dirs, files in os.walk(artifact_dir):
        dirs.sort()
        for fn in sorted(files):
            p = os.path.join(root, fn)
            rel = os.path.relpath(p, artifact_dir)
            h.update(rel.encode())
            h.update(str(os.path.getsize(p)).encode())
            if fn.endswith(".json"):
                with open(p, "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def capture(tiered, *, prefetcher=None, artifact_dir: Optional[str] = None) -> dict:
    """Serialize a warmed loader's residency state (plus the prefetcher's
    predictor, when armed) as a plain-JSON dict. Deterministic: the
    resident list is (stamp, key)-sorted — the same order eviction uses —
    so capture → save → load → capture round-trips byte-identically."""
    with tiered._lock:
        res = tiered.residency
        resident = sorted(
            ((res._stamp.get(k, 0), k) for k in res._lru),
            key=lambda sk: (sk[0], sk[1]),
        )
        snap = {
            "version": SNAPSHOT_VERSION,
            "artifact": {
                "dir": artifact_dir,
                "fingerprint": (
                    artifact_fingerprint(artifact_dir) if artifact_dir else None
                ),
            },
            "clock": res._clock,
            "resident": [[k, stamp] for stamp, k in resident],
        }
    predictor = getattr(prefetcher, "predictor", None)
    snap["predictor"] = predictor.to_dict() if predictor is not None else None
    return snap


def restore(
    tiered,
    snap: dict,
    *,
    prefetcher=None,
    artifact_dir: Optional[str] = None,
    strict: bool = True,
) -> dict:
    """Replay a snapshot onto a fresh loader; returns a report dict.

    Compatibility rule: when both the snapshot and the caller provide an
    artifact identity, they must match — ``strict=True`` raises on
    mismatch, ``strict=False`` skips the residency replay (cold join)
    and says so in the report. Version mismatches always raise.

    The replay faults units oldest-stamp-first with ``source="preload"``
    through the ordinary ``ensure`` path, so the restoring replica's own
    budget/arbiter govern what actually sticks: under a tighter budget
    the oldest restored units are the LRU victims, leaving the donor's
    hottest suffix resident. Donor LRU stamps are then reinstated for
    whatever survived, so the first organic evictions on the restored
    replica fall on the same units they would have on the donor.
    """
    version = snap.get("version")
    if version != SNAPSHOT_VERSION:
        raise ValueError(
            f"unsupported server snapshot version {version!r} (expected {SNAPSHOT_VERSION})"
        )
    report = {
        "requested": len(snap.get("resident", [])),
        "restored": 0,
        "skipped_foreign": 0,
        "moved_bytes": 0,
        "fingerprint_ok": None,
        "predictor_armed": False,
    }
    want = snap.get("artifact", {}).get("fingerprint")
    if want is not None and artifact_dir is not None:
        have = artifact_fingerprint(artifact_dir)
        report["fingerprint_ok"] = have == want
        if have != want:
            if strict:
                raise ValueError(
                    f"snapshot artifact fingerprint mismatch: snapshot has "
                    f"{want[:12]}…, {artifact_dir!r} has {have[:12]}… — a warm "
                    f"snapshot only restores against the same artifact"
                )
            return report  # cold join: residency replay skipped

    entries = [
        (k, stamp) for k, stamp in snap.get("resident", []) if k in tiered._all_units
    ]
    report["skipped_foreign"] = report["requested"] - len(entries)
    # oldest first, one ensure per unit: a batch would share a single LRU
    # stamp and load in store-offset order, so only per-unit replay makes
    # budget eviction shed exactly the donor's coldest units
    entries.sort(key=lambda ks: (ks[1], ks[0]))
    if entries:
        moved = 0
        for k, _ in entries:
            moved += tiered.ensure([k], source="preload")
        report["moved_bytes"] = moved
        with tiered._lock:
            res = tiered.residency
            stamps = dict(entries)
            survivors = [k for k, _ in entries if k in res._lru]
            for k in survivors:
                res._stamp[k] = stamps[k]
            # rebuild recency order to match the reinstated stamps (other
            # residents — e.g. a preloaded hot set — keep their stamps and
            # sort in by the same (stamp, key) rule eviction uses)
            ordered = sorted(
                res._lru, key=lambda k: (res._stamp.get(k, 0), k)
            )
            for k in ordered:
                res._lru.move_to_end(k)
            res._clock = max(res._clock, int(snap.get("clock", 0)))
            report["restored"] = len(survivors)

    if prefetcher is not None and snap.get("predictor") is not None:
        prefetcher.predictor = TransitionPredictor.from_dict(snap["predictor"])
        report["predictor_armed"] = True
    return report


def save(snap: dict, path: str) -> None:
    """Atomic temp+rename write (the repo-wide artifact commit rule)."""
    tmp = path + ".partial"
    with open(tmp, "w") as f:
        json.dump(snap, f, sort_keys=True)
    os.replace(tmp, path)


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
