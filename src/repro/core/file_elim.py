"""① Optional File Elimination — artifact-collection pruning.

The paper deletes four kinds of files that are *never loaded at runtime*
(virtualenv junk, compiled caches, dist-info, tests). The checkpoint-level
analogue removes whole *collections* from the serving artifact that the
serving entries can never consume:

  * optimizer state (Adam moments — 2× param bytes!),
  * EMA / Polyak shadows,
  * training-only auxiliaries (schedule step, rng, data-pipeline state),
  * stale temp/backup checkpoint files next to the manifest.

This is the "after1" stage of the paper's evaluation: it shrinks the bytes
*transmitted* (storage → host) before the Program Analyzer ever runs.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Iterable

from repro.utils.tree import flatten_with_paths, tree_bytes

# Collections known not to be consumed by any serving entry — the analogue
# of the paper's four optional-file types.
SERVING_OPTIONAL_COLLECTIONS: tuple[str, ...] = (
    "opt_state",  # Adam m/v — the "pip/setuptools directories"
    "ema",        # shadow params — the "compiled .pyc files"
    "rng",        # data/dropout rng — "dist-info"
    "data_state", # pipeline cursors — "tests directories"
    "metrics",
)

# File patterns next to a checkpoint that are never read at load time.
OPTIONAL_FILE_PATTERNS: tuple[str, ...] = (".tmp", ".bak", ".lock", ".partial")


@dataclass
class EliminationReport:
    kept_collections: list = field(default_factory=list)
    dropped_collections: dict = field(default_factory=dict)  # name -> bytes
    dropped_files: list = field(default_factory=list)

    @property
    def dropped_bytes(self) -> int:
        return sum(self.dropped_collections.values())


def eliminate_collections(
    artifact: dict,
    *,
    for_training: bool = False,
    optional: Iterable[str] = SERVING_OPTIONAL_COLLECTIONS,
) -> tuple[dict, EliminationReport]:
    """Split a full checkpoint tree into (serving artifact, report).

    ``artifact`` is the top-level checkpoint dict, e.g.
    ``{"params": …, "opt_state": …, "ema": …, "step": …}``. For training
    deployments nothing is dropped (every collection is reachable from the
    train entry's update rule).
    """
    report = EliminationReport()
    if for_training:
        report.kept_collections = list(artifact)
        return artifact, report
    optional = set(optional)
    kept = {}
    for name, coll in artifact.items():
        if name in optional:
            report.dropped_collections[name] = tree_bytes(coll)
        else:
            kept[name] = coll
            report.kept_collections.append(name)
    return kept, report


def eliminate_files(ckpt_dir: str, patterns: Iterable[str] = OPTIONAL_FILE_PATTERNS) -> list[str]:
    """Remove leftover temp/backup files in a checkpoint directory (the
    literal file-level half of ①). Returns removed paths."""
    removed = []
    if not os.path.isdir(ckpt_dir):
        return removed
    for name in os.listdir(ckpt_dir):
        if any(name.endswith(p) for p in patterns):
            path = os.path.join(ckpt_dir, name)
            try:
                os.remove(path)
                removed.append(path)
            except OSError:
                pass
    return removed
