"""⑦ Online re-tiering — live hot-set adaptation without restart
(DESIGN.md §12).

The §11 profile→re-tier cycle has a structural irony: applying the
re-tiered plan needs a restart, which is exactly the cold-start event the
paper optimizes away. The ``RetierDaemon`` closes that gap by applying
plan changes to the *running* server:

    serve ──▶ live AccessTrace ──rotate on cadence──▶ decayed merge ──▶
    replan_from_trace ──▶ apply in place:
        promote  = preload through the Prefetcher (or a between-batches
                   synchronous preload when no prefetcher is attached)
        demote   = budget-respecting eviction (never pinned / mid-step /
                   in-flight units — the §8.1 eviction rules unchanged)
    ... and retrain the TransitionPredictor from the merged trace;
    the artifact rewrite becomes an OPTIONAL periodic compaction.

The daemon is *passive*: it owns no thread. The serving loop calls
``maybe_tick()`` between batches (scheduler ``step()`` boundary, engine
``generate()`` step boundary) — never inside a step, so a tick can never
race the pinned working set of an in-flight step. Any thread may drive
``tick()``; all daemon state is behind one lock, and every mutation of
the loader goes through ``TieredParams``' own locked API.

Safety rules (DESIGN.md §12.1):

  * the tier-0 ⊇ entry-reachable invariant (§11.2) is re-checked with
    ``check_tier0_superset`` on EVERY plan application, against the
    required set computed once from the static analysis;
  * leaf tier promotion is disabled live (``promote_leaves=False``): a
    tier-1 → tier-0 flip changes the artifact layout, not the running
    tree — hot whole-leaf units are preloaded like any other promotion
    and move tiers at the next compaction;
  * applications only touch hot-set membership of units the live loader
    actually owns (``TieredParams`` units backed by the optional store);
  * demotion uses ``TieredParams.evict``, which skips pinned, LOADING,
    and already-cold units — a mid-step working set is untouchable.

Fleet federation (DESIGN.md §14): a ``FleetController`` drives N daemons
through two remote hooks — ``pull_window()`` hands the controller this
replica's rotated trace window (folding it into the local history as a
tick would), and ``apply_plan()`` applies a plan the controller replanned
from the *federated* history, under exactly the §12.1 safety rules (the
tier-0 ⊇ entry-reachable invariant is re-proved HERE, on the replica,
before any mutation — a corrupted or adversarial remote plan is rejected
whole).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.on_demand import AccessTrace, TieredParams
from repro.core.prefetch import Prefetcher, TransitionPredictor
from repro.core.retier import (
    RetierReport,
    check_tier0_superset,
    replan_from_trace,
    required_tier0,
    retier_artifact,
)


@dataclass
class RetierDaemonStats:
    """One daemon's lifetime accounting (printed by the launcher, asserted
    by tests/test_retier_daemon.py and benchmarks/bench_rq8_online.py)."""

    ticks: int = 0              # cadence firings (incl. skipped ones)
    skipped_empty: int = 0      # ticks with fewer than min_batches new batches
    errors: int = 0             # ticks that raised and were absorbed
    applies: int = 0            # ticks that applied a replanned hot set
    invariant_checks: int = 0   # tier-0 superset re-verifications (== applies)
    promoted_units: int = 0     # hot-set joins queued for preload
    demoted_units: int = 0      # hot-set drops submitted for eviction
    evicted_units: int = 0      # demotions that actually freed bytes
    evicted_bytes: int = 0
    preload_bytes: int = 0      # synchronous (no-prefetcher) preload traffic
    predictor_refreshes: int = 0
    compactions: int = 0        # periodic artifact rewrites (completed)
    compact_errors: int = 0     # background compactions that failed (absorbed)
    compact_skipped_inflight: int = 0  # cadence hits while one was running
    compact_wall_s: float = 0.0  # total worker-thread compaction wall time
    max_tick_s: float = 0.0     # slowest tick observed — the serve-path cost
    pulls: int = 0              # fleet window pulls (DESIGN.md §14.1)
    remote_applies: int = 0     # fleet plans applied via apply_plan()

    def to_dict(self) -> dict:
        return dict(self.__dict__)


class RetierDaemon:
    """Applies profile-guided re-tiering to a live ``TieredParams``.

    ``maybe_tick()`` fires after ``interval_steps`` serving steps or
    ``interval_s`` wall-clock seconds, whichever comes first. Each tick
    rotates the live trace (``TieredParams.rotate_trace``), folds the
    finished window into the decayed history (``AccessTrace.merge``,
    DESIGN.md §12.2), replans against the merged trace, and applies the
    plan in place under the §12.1 safety rules. With ``compact_every=N``
    every Nth application also rewrites the artifact out-of-place
    (``retier_artifact``) so the *next* cold start boots the adapted hot
    set — compaction is bookkeeping, not a serving event.
    """

    def __init__(
        self,
        tiered: TieredParams,
        reach,  # core.param_graph.ReachabilityReport
        *,
        prefetcher: Optional[Prefetcher] = None,
        interval_steps: int = 32,
        interval_s: Optional[float] = None,
        decay: float = 0.5,
        min_batches: int = 1,
        promote_min_faults: int = 1,
        max_promote_bytes: Optional[int] = None,
        refresh_predictor: bool = True,
        predictor_top_k: int = 8,
        compact_every: int = 0,
        artifact_dir: Optional[str] = None,
        compact_out_dir: Optional[str] = None,
    ):
        if interval_steps < 1:
            raise ValueError(f"interval_steps must be >= 1, got {interval_steps}")
        if not 0.0 <= decay <= 1.0:
            # fail HERE, not two ticks into serving when merge() first runs
            raise ValueError(f"decay must be in [0, 1], got {decay!r}")
        if compact_every and not artifact_dir:
            raise ValueError("compact_every needs artifact_dir to rewrite from")
        self.tiered = tiered
        self.reach = reach
        self.prefetcher = prefetcher
        self.interval_steps = interval_steps
        self.interval_s = interval_s
        self.decay = decay
        self.min_batches = max(1, min_batches)
        self.promote_min_faults = promote_min_faults
        self.max_promote_bytes = max_promote_bytes
        self.refresh_predictor = refresh_predictor
        self.predictor_top_k = predictor_top_k
        self.compact_every = compact_every
        self.artifact_dir = artifact_dir
        self.compact_out_dir = compact_out_dir
        self.stats = RetierDaemonStats()
        self.last_report: Optional[RetierReport] = None
        self.last_error: str = ""
        self.last_compaction: Optional[dict] = None  # meta of the last rewrite
        self.last_compact_error: str = ""
        self._lock = threading.Lock()
        # compaction worker state lives behind its OWN lock so the worker
        # thread never contends with (or deadlocks against) a serving tick
        # holding self._lock (DESIGN.md §17.3)
        self._compact_lock = threading.Lock()
        self._compact_thread: Optional[threading.Thread] = None
        self._merged: Optional[AccessTrace] = None
        self._unpulled: Optional[AccessTrace] = None  # accumulated for the fleet
        self._steps_since = 0
        self._last_tick_t = time.monotonic()
        # the invariant's required set is a function of the ORIGINAL plan
        # and the static analysis only (§11.2) — computed once, so no
        # sequence of applications can erode what must stay tier-0
        self._required = required_tier0(tiered.plan, reach)
        if tiered.trace is None:
            tiered.start_trace(AccessTrace())

    # -- cadence ----------------------------------------------------------------
    def maybe_tick(self, steps: int = 1) -> Optional[RetierReport]:
        """Count serving steps; tick when the step or wall-clock interval
        elapses. Called between batches — NEVER inside a step (the §12.1
        contract; enforced by call-site placement in engine/scheduler).

        Never raises: re-tiering is bookkeeping, not a serving event — a
        failing tick (compaction I/O, a store read during a sync preload)
        is absorbed into ``stats.errors``/``last_error`` and serving
        continues. An invariant failure aborts before any mutation; a
        mid-apply I/O failure leaves only committed evictions/preloads,
        which the loader treats as ordinary (refault or warm hit)."""
        with self._lock:
            self._steps_since += steps
            due = self._steps_since >= self.interval_steps or (
                self.interval_s is not None
                and time.monotonic() - self._last_tick_t >= self.interval_s
            )
            if not due:
                return None
            return self._tick_absorbed()

    def tick(self) -> Optional[RetierReport]:
        """Force one re-tier cycle now (tests, shutdown flushes). Same
        never-raises contract as ``maybe_tick``."""
        with self._lock:
            return self._tick_absorbed()

    def _tick_absorbed(self) -> Optional[RetierReport]:
        t0 = time.monotonic()
        try:
            return self._tick_locked()
        except Exception as e:  # degrade, don't kill the serving loop
            self.stats.errors += 1
            self.last_error = repr(e)
            return None
        finally:
            # the serve-path cost of a tick — with compaction off-thread
            # (§17.3) this stays flat even while an artifact rewrites
            self.stats.max_tick_s = max(
                self.stats.max_tick_s, time.monotonic() - t0)

    @property
    def merged_trace(self) -> Optional[AccessTrace]:
        """The decayed cross-window history the last replan saw."""
        with self._lock:
            return self._merged

    def trace_snapshot(self) -> AccessTrace:
        """History + the still-open live window, merged the same way the
        next tick would — what ``--profile-out`` saves when the daemon is
        on (the raw live window alone would miss everything already
        folded into the history)."""
        live = self.tiered.trace_snapshot()
        with self._lock:
            if self._merged is None:
                return live if live is not None else AccessTrace()
            if live is None or not live.batches:
                return self._merged
            return self._merged.merge(live, decay=self.decay)

    # -- one cycle ---------------------------------------------------------------
    def _tick_locked(self) -> Optional[RetierReport]:
        self.stats.ticks += 1
        self._steps_since = 0
        self._last_tick_t = time.monotonic()
        window = self.tiered.rotate_trace()
        if window is None:
            self.stats.skipped_empty += 1
            return None
        self._accumulate_unpulled(window)
        if window.batches < self.min_batches:
            # too little signal to replan on, but don't throw it away:
            # fold it in undecayed so slow traffic still accumulates
            self.stats.skipped_empty += 1
            if window.batches:
                self._merged = (
                    window if self._merged is None
                    else self._merged.merge(window, decay=1.0)
                )
            return None
        self._merged = (
            window if self._merged is None
            else self._merged.merge(window, decay=self.decay)
        )
        new_plan, report = replan_from_trace(
            self.tiered.plan,
            self._merged,
            self.reach,
            promote_min_faults=self.promote_min_faults,
            max_promote_bytes=self.max_promote_bytes,
            promote_leaves=False,  # §12.1: tier flips wait for compaction
        )
        self._apply(new_plan)
        self.last_report = report
        arb = getattr(self.tiered, "arbiter", None)
        if arb is not None:
            # host-governance feedback (DESIGN.md §13.2): hand the arbiter
            # this tenant's decayed heat for victim scoring, and fold the
            # tick's observed refault/overshoot deltas into share tuning
            arb.note_trace(self.tiered, self._merged)
            arb.observe_tick(self.tiered)
        return report

    # -- fleet hooks (DESIGN.md §14.1) -------------------------------------------
    def _accumulate_unpulled(self, window: AccessTrace) -> None:
        """Every rotated window (tick OR pull) also lands — undecayed,
        plain-sum — in the since-last-pull accumulator, so the fleet's
        ``pull_window`` sees everything this replica observed regardless
        of how its local tick cadence happened to chop the trace up. The
        undecayed sum keeps the pulled windows commutative across
        replicas (§14.1 rule 1)."""
        if not window.batches:
            return
        self._unpulled = (
            window if self._unpulled is None
            else self._unpulled.merge(window, decay=1.0)
        )

    def pull_window(self) -> Optional[AccessTrace]:
        """Rotate the live trace and hand the controller EVERYTHING this
        replica observed since the last pull (rotated window + any
        windows local ticks already consumed). The live window is ALSO
        folded into the local decayed history — exactly as a tick would —
        so ``trace_snapshot``/``--profile-out`` keep working, federated
        or not. Returns ``None`` when nothing new was observed (the
        controller skips this replica for the cycle)."""
        with self._lock:
            self.stats.pulls += 1
            window = self.tiered.rotate_trace()
            if window is not None and window.batches:
                self._accumulate_unpulled(window)
                self._merged = (
                    window if self._merged is None
                    else self._merged.merge(window, decay=self.decay)
                )
            out, self._unpulled = self._unpulled, None
            return out

    def apply_plan(
        self,
        new_plan,
        *,
        trace: Optional[AccessTrace] = None,
        sync_preload: bool = False,
    ) -> dict:
        """Apply a plan replanned ELSEWHERE (a ``FleetController``) under
        the same §12.1 safety rules as a local tick.

        Unlike ``tick()`` this RAISES on a tier-0 superset violation —
        strictly before any mutation — so the controller can quarantine a
        bad plan/replica without this replica's loader ever changing
        state. ``trace`` (the federated history) refreshes the predictor
        in place of the local history; ``sync_preload=True`` forces
        promotions through a synchronous between-batches preload even
        when a prefetcher is attached — the warm-bootstrap path, where
        the replica must be resident BEFORE admitting traffic."""
        with self._lock:
            n_promote, n_demote = self._apply(
                new_plan, sync_preload=sync_preload, refresh_from=trace
            )
            self.stats.remote_applies += 1
            return {"promoted": n_promote, "demoted": n_demote}

    def _apply(
        self, new_plan, *, sync_preload: bool = False, refresh_from=None
    ) -> tuple[int, int]:
        """Apply a replanned hot set to the running loader, in place."""
        # §12.1 rule 1: re-prove the invariant on EVERY application
        check_tier0_superset(new_plan, self._required)
        self.stats.invariant_checks += 1

        tiered = self.tiered
        owned = tiered._all_units
        promote: list[str] = []
        demote: list[str] = []
        for path, nd in new_plan.decisions.items():
            od = tiered.plan.decisions.get(path)
            if od is None or od.tier != 1 or nd.tier != 1:
                continue  # tier flips are compaction-only (§12.1 rule 2)
            old_res, new_res = set(od.resident_units), set(nd.resident_units)
            # replan orders promotions hottest-first; preserve that order
            promote.extend(
                k for k in nd.resident_units if k not in old_res and k in owned
            )
            demote.extend(
                k for k in od.resident_units if k not in new_res and k in owned
            )

        # demote FIRST: freed budget makes room for the incoming preloads
        if demote:
            evictions0 = tiered.stats.evictions
            freed = tiered.evict(demote)  # skips pinned/LOADING/cold (§8.1)
            self.stats.demoted_units += len(demote)
            self.stats.evicted_units += tiered.stats.evictions - evictions0
            self.stats.evicted_bytes += freed
        budget = tiered.residency.budget_bytes
        sync_path = sync_preload or self.prefetcher is None
        if promote and budget and sync_path:
            # budget-fit trim for the SYNCHRONOUS preload path only:
            # preloading past the budget would LRU-churn out the very units
            # just loaded (the replan ranks promotions but can't know this
            # replica's budget — under federation the controller doesn't
            # either, §14.1). Rank globally hottest-first by trace heat
            # (the per-decision diff above concatenates paths in plan
            # order), keep the prefix that fits the post-demotion headroom;
            # the tail stays demand-faultable. Async hints need neither the
            # sort nor the trim: the queue is loaded in order under LRU, so
            # what persists is its suffix, and interleaved demand faults
            # keep re-claiming what the workload actually needs.
            heat_src = refresh_from if refresh_from is not None else self._merged
            if heat_src is not None:
                heat = {
                    k: heat_src.touches.get(k, 0) + heat_src.faults.get(k, 0)
                    for k in promote
                }
                promote.sort(key=lambda k: -heat[k])  # stable: ties keep plan order
            resident = tiered.resident_keys
            headroom = budget - tiered.resident_bytes
            kept = []
            for k in promote:
                if k in resident:
                    kept.append(k)
                    continue
                nb = tiered.unit_charge(k)
                if nb <= headroom:
                    headroom -= nb
                    kept.append(k)
            promote = kept
        if promote:
            self.stats.promoted_units += len(promote)
            if self.prefetcher is not None and not sync_preload:
                # promotions ride the prefetch queue: claimed COLD→LOADING,
                # loaded off the serving thread, hit-accounted like any hint
                self.prefetcher.hint(promote)
            else:
                # no prefetcher (strict deployments) or a warm bootstrap:
                # preload synchronously HERE, between batches — bytes move,
                # but never inside a step and never on a request's fault path
                self.stats.preload_bytes += tiered.ensure(promote, source="preload")

        tiered.plan = new_plan
        self.stats.applies += 1

        src = refresh_from if refresh_from is not None else self._merged
        if self.refresh_predictor and self.prefetcher is not None and src is not None:
            # per-request transitions are coincidence-free (§12.3); fall
            # back to batch transitions when no scheduler attribution exists
            if src.request_transitions or src.transitions:
                self.prefetcher.predictor = TransitionPredictor.from_trace(
                    src, top_k=self.predictor_top_k, prefer_request=True)
                self.stats.predictor_refreshes += 1

        if self.compact_every and self.stats.applies % self.compact_every == 0:
            self._compact_async()
        return len(promote), len(demote)

    # -- background compaction (DESIGN.md §17.3) ---------------------------------
    def _compact_async(self) -> bool:
        """Kick one artifact rewrite on a worker thread. Serve-path guard:
        at most one in flight — a cadence hit while one runs is counted
        and dropped, never queued (the next cadence hit retries with a
        fresher plan anyway). The tick returns immediately; failures land
        in ``stats.compact_errors``/``last_compact_error`` exactly as tick
        failures land in ``stats.errors``. Called under ``self._lock``."""
        with self._compact_lock:
            if self._compact_thread is not None and self._compact_thread.is_alive():
                self.stats.compact_skipped_inflight += 1
                return False
            # snapshot plan/report/trace NOW, under the tick lock — the live
            # plan may change while the worker writes, and the rewrite must
            # be a consistent point-in-time artifact
            plan, rep, trace = self.tiered.plan, self.last_report, self._merged
            t = threading.Thread(
                target=self._compact_bg, args=(plan, rep, trace),
                name="retier-compact", daemon=True,
            )
            self._compact_thread = t
            t.start()
            return True

    def _compact_bg(self, plan, report, trace) -> None:
        t0 = time.monotonic()
        try:
            out = self.compact_out_dir or self.artifact_dir.rstrip("/") + "-compact"
            meta = retier_artifact(
                self.artifact_dir, plan, out_dir=out, report=report, trace=trace
            )
            with self._compact_lock:
                self.stats.compactions += 1
                self.last_compaction = meta
        except Exception as e:  # absorbed: compaction is bookkeeping (§12.1)
            with self._compact_lock:
                self.stats.compact_errors += 1
                self.last_compact_error = repr(e)
        finally:
            with self._compact_lock:
                self.stats.compact_wall_s += time.monotonic() - t0

    def join_compaction(self, timeout: Optional[float] = None) -> bool:
        """Wait for an in-flight background compaction (shutdown flushes,
        tests, benchmarks). Returns True when none is running afterwards."""
        with self._compact_lock:
            t = self._compact_thread
        if t is None:
            return True
        t.join(timeout)
        return not t.is_alive()

    def compact(self) -> dict:
        """Rewrite the artifact from the CURRENT live plan so the next cold
        start boots the adapted hot set, synchronously (tests, shutdown
        flushes — the periodic cadence uses ``_compact_async`` instead).
        Out-of-place + rename-committed (``retier_artifact``); the running
        server never re-reads it."""
        if not self.artifact_dir:
            raise ValueError("no artifact_dir configured for compaction")
        out = self.compact_out_dir or self.artifact_dir.rstrip("/") + "-compact"
        t0 = time.monotonic()
        meta = retier_artifact(
            self.artifact_dir, self.tiered.plan, out_dir=out,
            report=self.last_report, trace=self._merged,
        )
        with self._compact_lock:
            self.stats.compactions += 1
            self.stats.compact_wall_s += time.monotonic() - t0
            self.last_compaction = meta
        return meta
