"""② Application Entry Recognition.

The paper recognizes entries three ways (§4.1): (1) the deployment
configuration file, (2) source analysis matching handler signatures, and
(3) an explicit developer interface. The analogues here:

  1. ``DeploymentProfile`` — the deployment's declared entry set (a serving
     deployment declares ``prefill``/``decode_step``; a trainer declares
     ``train_step``; modality restrictions narrow the set further).
  2. automatic recognition from the ``Model`` facade — every model exposes
     ``entries()`` whose items carry a ``kind`` tag; ``recognize_entries``
     filters them by the profile exactly the way the paper matches
     ``(event, context)`` handler signatures.
  3. ``extra_entries`` — the explicit escape hatch.

Module-initialization functions (the paper's offline-profiled init list)
map to state initializers: cache/state init is always required before the
first decode, so ``init_cache`` is implicitly part of every decode
deployment — it consumes no parameters but pins the cache layout.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from repro.models.zoo import EntryPoint, Model


@dataclass(frozen=True)
class DeploymentProfile:
    """What this deployment serves — the FaaSLight configuration file.

    kinds       — which entry kinds the service exposes.
    modalities  — modal entries to keep warm ("text" always; "image"/"audio"
                  optional). Modal params outside this set become tier-1.
    hot_vocab_fraction — fraction of vocab row-groups resident at cold start.
    resident_experts   — experts resident per MoE layer at cold start
                         (-1 = all: baseline; 0 = none: strict).
    """

    name: str = "serving"
    kinds: tuple = ("prefill", "decode")
    modalities: tuple = ("text",)
    hot_vocab_fraction: float = 0.25
    resident_experts: int = 0
    min_tier1_bytes: int = 1 << 20  # leaves smaller than this stay tier-0
    vocab_row_group: int = 2048  # rows per on-demand vocab unit

    @property
    def is_training(self) -> bool:
        return "train" in self.kinds


TRAINING_PROFILE = DeploymentProfile(
    name="training", kinds=("train",), modalities=("text", "image", "audio"),
    hot_vocab_fraction=1.0, resident_experts=-1,
)
SERVING_PROFILE = DeploymentProfile(name="serving")
SERVING_MULTIMODAL_PROFILE = DeploymentProfile(
    name="serving-multimodal", modalities=("text", "image", "audio")
)


def recognize_entries(
    model: Model,
    profile: DeploymentProfile,
    *,
    B: int = 1,
    S: int = 128,
    extra_entries: Sequence[EntryPoint] = (),
) -> list[EntryPoint]:
    """Signature-match the model's registered entries against the profile.

    Mirrors the paper's strategy order: the profile (config file) selects
    kinds; the ``kind`` tag on each entry is the handler-signature match;
    ``extra_entries`` is the explicit interface.
    """
    multimodal = any(m in profile.modalities for m in ("image", "audio"))
    out: list[EntryPoint] = []
    for ep in model.entries(B=B, S=S):
        if ep.kind not in profile.kinds:
            continue
        is_text_only = ep.name.endswith("_text_only")
        if multimodal and is_text_only:
            # the modal variant subsumes text-only reachability; keep both
            # only when the deployment serves mixed traffic (it does: text
            # requests still arrive) — include, it is cheap to trace.
            pass
        if not multimodal and not is_text_only:
            # text-only deployment: skip modal variants so modal params are
            # *unreachable* (the whisper-encoder / VLM-cross case).
            has_modal_twin = any(
                e.name == ep.name + "_text_only" for e in model.entries(B=B, S=S)
            )
            if has_modal_twin:
                continue
        out.append(ep)
    out.extend(extra_entries)
    if not out:
        raise ValueError(
            f"no entries recognized for profile {profile.name!r} "
            f"(kinds={profile.kinds}) — the paper's strategy-3 escape hatch: "
            "pass extra_entries explicitly"
        )
    return out
