"""④ On-demand loading — the ``rewrite_template`` analogue (DESIGN.md §8).

The paper rewrites each optional function to a 2-line stub that, on first
invocation, reads the lightweight file, materializes the separated code, and
executes it. Here the "stub" is a *placeholder buffer*: tier-1 leaves start
as zero-filled device arrays (correctly sharded, so the compiled executable
is identical to the fully-loaded one); the ``OnDemandLoader`` faults real
bytes in unit-by-unit when requests need them.

Correctness backstop, as in the paper: a misprediction (cold expert routed
to, cold vocab row sampled) is a *latency* event — fetch + decompress +
device upload + row scatter — never a failure. ``ensure()`` is idempotent
and thread-safe.

Beyond the seed's monotone loaded-set, residency is a per-unit state
machine governed by a ``ResidencyManager`` (DESIGN.md §8.1):

    COLD ──ensure()/prefetch──▶ LOADING ──install──▶ RESIDENT
      ▲                                                 │
      └───────────── evict (LRU, unpinned) ◀────────────┘

A configurable device-bytes budget bounds the RESIDENT set; when an
install would exceed it, least-recently-used unpinned units are evicted
back to placeholder zeros before the new bytes land — resident bytes never
exceed the budget while any victim is evictable. Eviction never touches a
LOADING unit (an in-flight read can't be yanked) and never touches a
pinned unit (``ensure(pin=True)`` / ``release()`` bracket a request step).

Telemetry (DESIGN.md §11): ``start_trace()`` attaches an ``AccessTrace``
that records every request-path ``ensure()`` batch — per-unit fault and
touch counts, request-phase tags, co-access pairs, and batch→batch
transitions. The trace is the input to the profile-guided replanner
(``core/retier.py``) and the predictive prefetcher (``core/prefetch.py``).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optional_store import OptionalStore, ReadStats
from repro.core.partition import TierPlan, Unit
from repro.utils.tree import flatten_with_paths, tree_from_flat

# residency states (DESIGN.md §8.1)
COLD = "cold"          # placeholder zeros on device; bytes not charged
LOADING = "loading"    # a read/decode/upload is in flight; never evictable
RESIDENT = "resident"  # real bytes on device; charged against the budget


@dataclass
class LoadEvent:
    key: str
    nbytes: int
    fetch_s: float
    upload_s: float
    t: float = 0.0          # monotonic completion time
    source: str = "fault"   # "fault" | "prefetch" | "preload"
    phase: str = ""         # request phase at load time ("prefill" | "decode" | "")


class AccessTrace:
    """Demand-access telemetry for profile-guided re-tiering (DESIGN.md §11).

    One trace aggregates every *request-path* access batch (an
    ``ensure(source="fault")`` call) into the four signals the replanner
    and the predictive prefetcher consume:

      * ``touches[key]``  — demand touches, warm or cold (a preloaded
        resident that is never touched is a demotion candidate);
      * ``faults[key]``   — demand touches that found the unit not yet
        RESIDENT (the cold-start misses re-tiering should promote away);
      * ``phases[key]``   — per-phase fault counts (``prefill``/``decode``
        tags set by the engine via ``TieredParams.set_phase``);
      * ``pairs`` / ``transitions`` — co-access pairs within one batch and
        batch→next-batch unit transitions, the predictor's raw material.

    Pair/transition recording is skipped for batches larger than
    ``max_assoc_batch`` keys (a bulk ``ensure_all`` would otherwise record
    a quadratic blob of meaningless associations). Serialization is
    deterministic: ``to_json`` sorts every key so record → JSON → replan
    is reproducible byte-for-byte (tests/test_retier.py).

    **Request attribution** (DESIGN.md §12.3): in traffic mode one demand
    batch unions every active slot's accesses, so ``pairs``/``transitions``
    conflate per-request patterns with cross-request coincidence. The
    scheduler additionally calls ``record_request(rid, keys)`` with each
    request's *own* accesses per step; those land in ``request_pairs`` /
    ``request_transitions`` — the coincidence-free association signal.
    ``end_request(rid)`` drops the per-request chain state at retirement
    so a long-lived trace never links across unrelated requests.

    **Higher-order signals** (DESIGN.md §14.2, schema v3): alongside the
    first-order ``transitions``, ``record`` keeps

      * ``phase_transitions[phase][a][b]`` — the same batch→next-batch
        counts split by the *current* batch's request phase, so a
        predictor can rank prefill successors and decode successors
        separately (a unit hot during prefill is often cold in decode);
      * ``transitions2[(a2, a1)][b]`` — second-order context: ``a2`` from
        the batch two steps back, ``a1`` from the previous batch, ``b``
        in the current one. Recorded only for batches of at most
        ``max_order2_batch`` keys (the pair fan-out is quadratic where
        first-order is linear).

    **Lifecycle** (DESIGN.md §12.2): one trace = one observation window.
    ``merge(newer, decay=d)`` folds windows across cadence ticks (and
    across replicas): this window's counts are scaled by ``d`` before the
    newer window's are added, so the hot set tracks shifting workloads
    (``d=1`` → plain lifetime sum, ``d=0`` → newest window only). Entries
    decaying below ``prune_below`` are dropped. ``merge_all`` folds a
    *list* of same-tick windows (one per fleet replica) with plain-sum
    semantics — commutative and associative, so the fleet plan cannot
    depend on replica pull order (DESIGN.md §14.1). The schema carries a
    ``version`` field next to artifact.json's; merging or loading across
    schema versions raises (v1/v2 documents, which predate the request-
    attribution and higher-order fields respectively, still load).
    """

    VERSION = 3

    def __init__(self, *, max_assoc_batch: int = 64, max_order2_batch: int = 8):
        self.version = self.VERSION
        self.max_assoc_batch = max_assoc_batch
        self.max_order2_batch = max_order2_batch
        self.batches = 0
        self.touches: dict[str, int] = {}
        self.faults: dict[str, int] = {}
        self.phases: dict[str, dict[str, int]] = {}
        self.pairs: dict[tuple, int] = {}           # (a, b) with a < b
        self.transitions: dict[str, dict[str, int]] = {}
        self.request_pairs: dict[tuple, int] = {}   # same-request co-access
        self.request_transitions: dict[str, dict[str, int]] = {}
        # schema v3: phase-conditioned + second-order successor counts
        self.phase_transitions: dict[str, dict[str, dict[str, int]]] = {}
        self.transitions2: dict[tuple, dict[str, int]] = {}  # (a2, a1) -> {b: n}
        self._last_batch: list[str] = []
        self._last2_batch: list[str] = []  # the batch before _last_batch
        self._last_by_request: dict[int, list[str]] = {}

    def record(self, keys: Iterable[str], cold: Iterable[str], phase: str = "") -> None:
        """Record one demand batch. ``keys`` is everything the request
        touched; ``cold`` the subset that was not RESIDENT. Caller holds
        the owning loader's lock (one writer at a time)."""
        keys, cold = list(keys), list(cold)
        if not keys:
            return
        self.batches += 1
        for k in keys:
            self.touches[k] = self.touches.get(k, 0) + 1
        for k in cold:
            self.faults[k] = self.faults.get(k, 0) + 1
            by_phase = self.phases.setdefault(k, {})
            by_phase[phase] = by_phase.get(phase, 0) + 1
        if len(keys) <= self.max_assoc_batch:
            for i, a in enumerate(keys):
                for b in keys[i + 1:]:
                    if a != b:
                        pair = (a, b) if a < b else (b, a)
                        self.pairs[pair] = self.pairs.get(pair, 0) + 1
            # _last_batch is [] or an under-cap batch by construction
            cur = set(keys)
            by_phase = self.phase_transitions.setdefault(phase, {})
            for a in self._last_batch:
                succ = [b for b in cur if b != a]
                if not succ:
                    continue  # never leave an empty successor dict behind
                nxt = self.transitions.setdefault(a, {})
                pnxt = by_phase.setdefault(a, {})
                for b in succ:
                    nxt[b] = nxt.get(b, 0) + 1
                    pnxt[b] = pnxt.get(b, 0) + 1
            if not by_phase:
                del self.phase_transitions[phase]
            # second-order context (DESIGN.md §14.2): the quadratic
            # (a2, a1) fan-out gets a tighter cap than first-order
            cap2 = self.max_order2_batch
            if (
                len(keys) <= cap2
                and 0 < len(self._last_batch) <= cap2
                and 0 < len(self._last2_batch) <= cap2
            ):
                for a2 in self._last2_batch:
                    for a1 in self._last_batch:
                        succ = [b for b in cur if b != a1 and b != a2]
                        if not succ:
                            continue
                        nxt2 = self.transitions2.setdefault((a2, a1), {})
                        for b in succ:
                            nxt2[b] = nxt2.get(b, 0) + 1
            self._last2_batch = self._last_batch
            self._last_batch = keys
        else:
            self._last_batch = []
            self._last2_batch = []

    # -- request attribution (DESIGN.md §12.3) ---------------------------------
    def record_request(self, rid: int, keys: Iterable[str]) -> None:
        """Record the units ONE request accessed this step. Unlike
        ``record`` (which sees the scheduler's unioned batch), pairs and
        step→step transitions recorded here are same-request by
        construction — the replanner/predictor can separate per-request
        patterns from cross-request coincidence. Caller holds the owning
        loader's lock."""
        keys = list(dict.fromkeys(keys))
        if not keys or len(keys) > self.max_assoc_batch:
            self._last_by_request.pop(rid, None)
            return
        for i, a in enumerate(keys):
            for b in keys[i + 1:]:
                pair = (a, b) if a < b else (b, a)
                self.request_pairs[pair] = self.request_pairs.get(pair, 0) + 1
        cur = set(keys)
        for a in self._last_by_request.get(rid, ()):
            succ = [b for b in cur if b != a]
            if not succ:
                continue
            nxt = self.request_transitions.setdefault(a, {})
            for b in succ:
                nxt[b] = nxt.get(b, 0) + 1
        self._last_by_request[rid] = keys

    def end_request(self, rid: int) -> None:
        """Retire one request's chain state: its last step never links to
        whatever unrelated request next reuses the slot."""
        self._last_by_request.pop(rid, None)

    # -- window merging (DESIGN.md §12.2) ---------------------------------------
    def merge(self, newer: "AccessTrace", *, decay: float = 1.0,
              prune_below: float = 0.5) -> "AccessTrace":
        """Fold a newer observation window onto this one: every count here
        is scaled by ``decay`` (0 ≤ decay ≤ 1), then the newer window's
        counts are added; entries below ``prune_below`` after scaling are
        dropped (a unit nobody touches for a few windows genuinely leaves
        the profile instead of lingering at 1e-9). Returns a NEW trace;
        neither input is mutated, and the merged trace carries no
        in-flight chain state (``_last_batch``/``_last_by_request``).
        Deterministic: same inputs → byte-identical ``to_json``. Raises on
        schema-version mismatch, and on ``newer is self`` (an aliased
        merge would read counts it is also summing into — fold a window
        into a *different* history object, or snapshot first)."""
        if newer is self:
            raise ValueError(
                "cannot merge an AccessTrace into itself (aliasing); "
                "merge a rotated window or a snapshot copy instead"
            )
        if not 0.0 <= decay <= 1.0:
            raise ValueError(f"decay must be in [0, 1], got {decay!r}")
        if self.version != newer.version:
            raise ValueError(
                f"cannot merge AccessTrace schema v{self.version} with v{newer.version}"
            )

        def norm(v):
            # canonical numbers: integral floats store as ints, so a
            # decay=1 merge of int windows round-trips byte-identically
            return int(v) if isinstance(v, float) and v.is_integer() else v

        def counts(old: dict, new: dict) -> dict:
            out: dict = {}
            for k, v in old.items():
                sv = v if decay == 1 else v * decay
                if sv >= prune_below:
                    out[k] = norm(sv)
            for k, v in new.items():
                out[k] = norm(out.get(k, 0) + v)
            return {k: v for k, v in out.items() if v >= prune_below}

        def nested(old: dict, new: dict) -> dict:
            sub = {k: counts(old.get(k, {}), new.get(k, {}))
                   for k in set(old) | set(new)}
            return {k: v for k, v in sub.items() if v}

        merged = AccessTrace(
            max_assoc_batch=max(self.max_assoc_batch, newer.max_assoc_batch),
            max_order2_batch=max(self.max_order2_batch, newer.max_order2_batch))
        merged.batches = norm(
            (self.batches if decay == 1 else self.batches * decay) + newer.batches)
        merged.touches = counts(self.touches, newer.touches)
        merged.faults = counts(self.faults, newer.faults)
        merged.phases = nested(self.phases, newer.phases)
        merged.pairs = counts(self.pairs, newer.pairs)
        merged.transitions = nested(self.transitions, newer.transitions)
        merged.request_pairs = counts(self.request_pairs, newer.request_pairs)
        merged.request_transitions = nested(
            self.request_transitions, newer.request_transitions)
        merged.phase_transitions = {
            ph: sub
            for ph in set(self.phase_transitions) | set(newer.phase_transitions)
            if (sub := nested(self.phase_transitions.get(ph, {}),
                              newer.phase_transitions.get(ph, {})))
        }
        merged.transitions2 = nested(self.transitions2, newer.transitions2)
        return merged

    @classmethod
    def merge_all(cls, windows, *, prune_below: float = 0.5) -> "AccessTrace":
        """Fold a list of observation windows into one trace with *plain
        sum* semantics (``decay=1``). Integer counts make the sum
        commutative and associative, so the result — and any fleet plan
        derived from it — is independent of the order replicas were
        pulled in (DESIGN.md §14.1, property-tested in tests/test_fleet.py).
        An empty window list returns an empty trace (a fleet tick where
        every replica was idle is a no-op, not an error)."""
        out = cls()
        for w in windows:
            out = out.merge(w, decay=1.0, prune_below=prune_below)
        return out

    # -- serialization (deterministic; the --profile-out format) --------------
    def to_dict(self) -> dict:
        return {
            "version": self.version,
            "batches": self.batches,
            "touches": {k: self.touches[k] for k in sorted(self.touches)},
            "faults": {k: self.faults[k] for k in sorted(self.faults)},
            "phases": {
                k: {p: v[p] for p in sorted(v)}
                for k, v in sorted(self.phases.items())
            },
            "pairs": [[a, b, self.pairs[(a, b)]] for a, b in sorted(self.pairs)],
            "transitions": {
                k: {n: v[n] for n in sorted(v)}
                for k, v in sorted(self.transitions.items())
            },
            "request_pairs": [
                [a, b, self.request_pairs[(a, b)]] for a, b in sorted(self.request_pairs)
            ],
            "request_transitions": {
                k: {n: v[n] for n in sorted(v)}
                for k, v in sorted(self.request_transitions.items())
            },
            "phase_transitions": {
                ph: {
                    k: {n: v[n] for n in sorted(v)}
                    for k, v in sorted(tbl.items())
                }
                for ph, tbl in sorted(self.phase_transitions.items())
            },
            # tuple keys flatten to sorted [a2, a1, b, n] rows (JSON-safe)
            "transitions2": [
                [a2, a1, b, v[b]]
                for (a2, a1), v in sorted(self.transitions2.items())
                for b in sorted(v)
            ],
        }

    def to_json(self) -> str:
        import json

        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "AccessTrace":
        # older documents still load — v1 predates request attribution,
        # v2 the higher-order tables; the absent fields default empty.
        # Anything else is a schema we don't know.
        if d.get("version") not in (1, 2, cls.VERSION):
            raise ValueError(f"unsupported AccessTrace version {d.get('version')!r}")
        t = cls()
        # counts stay as-parsed (int, or float from a decayed merge) so a
        # save → load → save round-trip is byte-identical
        t.batches = d.get("batches", 0)
        t.touches = dict(d.get("touches", {}))
        t.faults = dict(d.get("faults", {}))
        t.phases = {k: dict(v) for k, v in d.get("phases", {}).items()}
        t.pairs = {(a, b): n for a, b, n in d.get("pairs", [])}
        t.transitions = {k: dict(v) for k, v in d.get("transitions", {}).items()}
        t.request_pairs = {(a, b): n for a, b, n in d.get("request_pairs", [])}
        t.request_transitions = {
            k: dict(v) for k, v in d.get("request_transitions", {}).items()
        }
        t.phase_transitions = {
            ph: {k: dict(v) for k, v in tbl.items()}
            for ph, tbl in d.get("phase_transitions", {}).items()
        }
        for a2, a1, b, n in d.get("transitions2", []):
            t.transitions2.setdefault((a2, a1), {})[b] = n
        return t

    @classmethod
    def from_json(cls, s: str) -> "AccessTrace":
        import json

        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        """Atomic temp+rename write (the same commit rule every artifact
        writer in this repo follows)."""
        import json
        import os

        tmp = path + ".partial"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "AccessTrace":
        import json

        with open(path) as f:
            return cls.from_dict(json.load(f))


@dataclass
class LoaderStats:
    events: list = field(default_factory=list)
    misses: int = 0          # synchronous request-path loads
    hits: int = 0            # already-resident touches
    prefetch_hits: int = 0   # first demand-touch of a prefetch-loaded unit
    prefetch_waits: int = 0  # demand overlapped an in-flight prefetch load
    evictions: int = 0
    evicted_bytes: int = 0
    refaults: int = 0        # loads of a previously-evicted unit
    stalls: list = field(default_factory=list)  # per-ensure miss-stall seconds
    preads_issued: int = 0     # pread syscalls the demand path issued
    frames_fetched: int = 0    # store frames those reads delivered
    coalesced_bytes: int = 0   # payload bytes arriving via multi-frame preads

    @property
    def total_miss_bytes(self) -> int:
        return sum(e.nbytes for e in self.events if e.source != "prefetch")

    @property
    def request_fault_bytes(self) -> int:
        """Bytes moved synchronously ON the request path (source="fault"
        only — excludes cold-start preload and background prefetch). The
        quantity one profile→re-tier cycle should shrink (RQ7)."""
        return sum(e.nbytes for e in self.events if e.source == "fault")

    @property
    def total_loaded_bytes(self) -> int:
        return sum(e.nbytes for e in self.events)

    @property
    def total_miss_s(self) -> float:
        return sum(e.fetch_s + e.upload_s for e in self.events if e.source != "prefetch")

    @property
    def prefetch_hit_rate(self) -> float:
        """Of demand-touched cold units, fraction hidden by the prefetcher."""
        n = self.prefetch_hits + self.prefetch_waits + self.misses
        return (self.prefetch_hits + self.prefetch_waits) / n if n else 0.0

    def stall_percentile(self, q: float) -> float:
        if not self.stalls:
            return 0.0
        return float(np.percentile(np.asarray(self.stalls), q))


class ResidencyManager:
    """Per-unit residency state machine + device-bytes budget accounting.

    All mutation happens under a shared lock (the owner's ``RLock``); a
    condition on that lock lets demand loads wait for in-flight prefetch
    loads instead of duplicating the read. LRU order is an ``OrderedDict``
    over RESIDENT keys, refreshed on every touch; eviction walks it oldest
    first, skipping pinned units.
    """

    def __init__(self, lock: threading.RLock, *, budget_bytes: Optional[int] = None):
        self._lock = lock
        self.cv = threading.Condition(lock)
        self.budget_bytes = budget_bytes
        self._state: dict[str, str] = {}
        self._nbytes: dict[str, int] = {}
        self._pins: dict[str, int] = {}
        # logical access clock: advanced once per public access (one ensure
        # batch = one tick), stamped onto keys at commit/touch. Keys
        # committed by the same batch share a stamp; ``select_victims``
        # breaks those ties by key so eviction order never depends on dict
        # insertion order (reproducible rq2/rq8 byte counts).
        self._clock = 0
        self._stamp: dict[str, int] = {}
        # ordered set of RESIDENT keys, old→new; dict order IS the recency
        self._lru: OrderedDict[str, None] = OrderedDict()
        self._loaders: dict[str, str] = {}   # LOADING key -> claimant source
        self._sources: dict[str, str] = {}   # RESIDENT key -> load source
        self._unclaimed_prefetch: set[str] = set()  # prefetched, not yet demanded
        self._evicted_once: set[str] = set()
        self.resident_bytes = 0
        self.max_resident_bytes = 0  # high-water mark (budget invariant probe)
        self.overshoot_events = 0    # installs that couldn't make room

    # -- queries (lock held by caller or uncontended reads) -------------------
    def state_of(self, key: str) -> str:
        return self._state.get(key, COLD)

    def is_resident(self, key: str) -> bool:
        return self._state.get(key) == RESIDENT

    @property
    def resident_keys(self) -> set:
        with self._lock:
            return set(self._lru)

    def pins_of(self, key: str) -> int:
        return self._pins.get(key, 0)

    def loader_of(self, key: str) -> str:
        """Source that owns an in-flight LOADING key ("" if none)."""
        return self._loaders.get(key, "")

    def charged_bytes(self) -> int:
        """Recomputed sum of per-key charges over the RESIDENT set — the
        audit cross-check against the running ``resident_bytes`` counter
        (caller holds the lock)."""
        return sum(self._nbytes.get(k, 0) for k in self._lru)

    def advance_clock(self) -> int:
        """One tick per public access batch (caller holds the lock). Every
        commit/touch within the batch shares the new stamp."""
        self._clock += 1
        return self._clock

    # -- transitions (caller MUST hold the lock) ------------------------------
    def begin_load(self, key: str, source: str) -> bool:
        """COLD → LOADING. False if already loading/resident (caller skips
        or waits); the claimant that got True owns the read."""
        if self._state.get(key, COLD) != COLD:
            return False
        self._state[key] = LOADING
        self._loaders[key] = source
        return True

    def commit_load(self, key: str, nbytes: int, source: str) -> None:
        """LOADING → RESIDENT: charge the budget, make the key MRU."""
        assert self._state.get(key) == LOADING, (key, self._state.get(key))
        self._state[key] = RESIDENT
        self._nbytes[key] = nbytes
        self._sources[key] = source
        self._loaders.pop(key, None)
        self._lru[key] = None
        self._lru.move_to_end(key)
        self._stamp[key] = self._clock
        if source == "prefetch":
            self._unclaimed_prefetch.add(key)
        self.resident_bytes += nbytes
        self.max_resident_bytes = max(self.max_resident_bytes, self.resident_bytes)
        self.cv.notify_all()

    def abort_load(self, key: str) -> None:
        """LOADING → COLD (read failed or prefetcher shut down mid-claim)."""
        if self._state.get(key) == LOADING:
            self._state[key] = COLD
            self._loaders.pop(key, None)
            self.cv.notify_all()

    def touch(self, key: str, *, claim_prefetch: bool = True) -> str:
        """Refresh LRU recency on an access. With ``claim_prefetch`` (demand
        touches) returns "prefetch" exactly once per prefetch-loaded unit —
        the hit-accounting credit; hint touches pass False so they don't
        consume the credit a later demand touch should claim."""
        if key in self._lru:
            self._lru.move_to_end(key)
            self._stamp[key] = self._clock
        if claim_prefetch and key in self._unclaimed_prefetch:
            self._unclaimed_prefetch.discard(key)
            return "prefetch"
        return ""

    def pin(self, keys: Iterable[str]) -> None:
        for k in keys:
            self._pins[k] = self._pins.get(k, 0) + 1

    def release(self, keys: Iterable[str]) -> None:
        for k in keys:
            n = self._pins.get(k, 0) - 1
            if n <= 0:
                self._pins.pop(k, None)
            else:
                self._pins[k] = n

    def select_victims(self, need_bytes: int) -> list[str]:
        """Oldest-first unpinned RESIDENT keys freeing ≥ need_bytes (best
        effort — may free less if the evictable pool is too small). Keys
        with equal access stamps (committed by one batched ensure) tie-break
        by key, so eviction order is deterministic regardless of the dict
        insertion order the batch happened to produce."""
        victims, freed = [], 0
        for k in sorted(self._lru, key=lambda k: (self._stamp.get(k, 0), k)):
            if freed >= need_bytes:
                break
            if self._pins.get(k, 0) > 0:
                continue
            victims.append(k)
            freed += self._nbytes.get(k, 0)
        return victims

    def evict_commit(self, key: str) -> int:
        """RESIDENT → COLD after the placeholder reinstall; credits bytes."""
        assert self._state.get(key) == RESIDENT and self._pins.get(key, 0) == 0
        nb = self._nbytes.pop(key, 0)
        self._state[key] = COLD
        self._lru.pop(key, None)
        self._stamp.pop(key, None)
        self._sources.pop(key, None)
        self._unclaimed_prefetch.discard(key)
        self._evicted_once.add(key)
        self.resident_bytes -= nb
        return nb

    def was_evicted(self, key: str) -> bool:
        return key in self._evicted_once

    def wait_resident(self, key: str, timeout: float = 30.0) -> bool:
        """Block until ``key`` leaves LOADING (caller holds the lock via the
        condition). True if it became RESIDENT; False on abort/timeout."""
        deadline = time.monotonic() + timeout
        while self._state.get(key) == LOADING:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            self.cv.wait(remaining)
        return self._state.get(key) == RESIDENT


class TieredParams:
    """The live parameter tree of a cold-started server.

    * tier-0 leaves: real weights, device-resident from cold start.
    * tier-1 leaves: allocated at full shape (placeholder zeros) and filled
      in-place per unit (experts: ``at[e].set``; rows: row-slice scatter;
      whole-leaf: swap). Allocation is eager but *bytes* move lazily —
      device memory for tier-1 is the explicit rent paid for the identical
      executable; strict deployments can zero-page it.

    ``tree()`` returns the current param pytree to pass into compiled fns.
    ``device_budget_bytes`` bounds real-resident tier-1 bytes; see
    ``ResidencyManager`` for the eviction contract.
    """

    def __init__(
        self,
        tree: dict,
        plan: TierPlan,
        store: Optional[OptionalStore],
        *,
        device_budget_bytes: Optional[int] = None,
        shard_divisors: Optional[dict] = None,
    ):
        self._tree = tree
        self._flat = dict(flatten_with_paths(tree))
        self.plan = plan
        self.store = store
        # mesh-sharded serving (DESIGN.md §15.1): per-leaf shard counts.
        # A unit of a leaf split D ways costs nbytes/D *per device*, so the
        # budget/arbiter charge is divided by the owning leaf's divisor
        # (absent → 1 → byte-identical to unsharded accounting). IO stats
        # (LoadEvent, faulted_bytes) always keep raw host bytes.
        self._shard_div: dict[str, int] = dict(shard_divisors or {})
        self.stats = LoaderStats()
        self.trace: Optional[AccessTrace] = None  # attach via start_trace()
        self._phase = ""  # request phase tag for trace/LoadEvent (DESIGN.md §11)
        self._lock = threading.RLock()
        self.residency = ResidencyManager(self._lock, budget_bytes=device_budget_bytes)
        # host-level governance (core/arbiter.py, DESIGN.md §13): when a
        # HostArbiter registers this instance it sets these, disables the
        # private budget, and the install paths below route make-room
        # through it — called with NO lock held (arbiter lock orders
        # before every tenant lock).
        self.arbiter = None
        self.tenant_name = ""
        self._all_units: dict[str, Unit] = {}
        for d in plan.decisions.values():
            for u in d.units:
                self._all_units[u.key] = u

    # -- telemetry (DESIGN.md §11) --------------------------------------------
    def start_trace(self, trace: Optional[AccessTrace] = None) -> AccessTrace:
        """Attach an ``AccessTrace``; every subsequent request-path
        ``ensure()`` batch is recorded into it. Returns the trace."""
        with self._lock:
            self.trace = trace if trace is not None else AccessTrace()
            return self.trace

    def rotate_trace(self, fresh: Optional[AccessTrace] = None) -> Optional[AccessTrace]:
        """Atomically swap in a fresh trace and return the finished window
        (None if tracing was never started). The re-tiering daemon's
        cadence primitive (DESIGN.md §12): the returned window is no
        longer written to and can be read/merged without the loader lock."""
        with self._lock:
            old = self.trace
            if old is not None:
                self.trace = fresh if fresh is not None else AccessTrace(
                    max_assoc_batch=old.max_assoc_batch)
            return old

    def trace_snapshot(self) -> Optional[AccessTrace]:
        """A consistent copy of the live trace (None if tracing is off) —
        readable while request threads keep recording into the original."""
        with self._lock:
            return AccessTrace.from_dict(self.trace.to_dict()) if self.trace else None

    def record_request(self, rid: int, keys: Iterable[str]) -> None:
        """Attribute one request's step accesses in the live trace
        (scheduler-aware profiling, DESIGN.md §12.3). No-op without a trace."""
        with self._lock:
            if self.trace is not None:
                self.trace.record_request(rid, keys)

    def end_request(self, rid: int) -> None:
        with self._lock:
            if self.trace is not None:
                self.trace.end_request(rid)

    def set_phase(self, phase: str) -> None:
        """Tag subsequent loads/trace batches with a request phase
        ("prefill" | "decode" | ""). Set by the engine around each step."""
        self._phase = phase

    # -- residency ----------------------------------------------------------
    def is_resident(self, key: str) -> bool:
        return self.residency.is_resident(key)

    def mark_resident(self, key: str) -> None:
        """Force-mark without moving bytes (testing/bootstrap escape hatch)."""
        with self._lock:
            if self.residency.begin_load(key, "mark"):
                self.residency.advance_clock()
                self.residency.commit_load(key, self.unit_charge(key), "mark")

    @property
    def resident_keys(self) -> set:
        return self.residency.resident_keys

    @property
    def resident_bytes(self) -> int:
        return self.residency.resident_bytes

    def resident_fraction(self) -> float:
        n = len(self._all_units)
        return len(self.residency.resident_keys) / n if n else 1.0

    def _unit_nbytes(self, key: str) -> int:
        u = self._all_units.get(key)
        if u is not None and u.nbytes:
            return u.nbytes
        if self.store is not None and key in self.store.entries:
            return self.store.entries[key].rsize
        return 0

    def unit_charge(self, key: str, nbytes: Optional[int] = None) -> int:
        """Device-budget charge for one unit: its host bytes divided by the
        owning leaf's shard count (§15.1 per-shard accounting; ceil so a
        charge is never rounded to free). Equal to the raw bytes when the
        leaf is replicated or no mesh is attached."""
        nb = self._unit_nbytes(key) if nbytes is None else nbytes
        u = self._all_units.get(key)
        div = self._shard_div.get(u.path, 1) if u is not None else 1
        return nb if div <= 1 else -(-nb // div)

    # -- the rewrite_template analogue ---------------------------------------
    def ensure(self, keys: Iterable[str], *, pin: bool = False, source: str = "fault") -> int:
        """Fault in the given unit keys. Returns bytes moved (0 = warm hit).

        This is the two-line stub body grown into the state machine: check
        residency, claim COLD keys, read+decode off the lock, evict-to-fit,
        install, and wait out any loads another thread (the prefetcher)
        already owns. Idempotent and thread-safe; with ``pin=True`` the
        keys stay unevictable until a matching ``release()``.
        """
        keys = list(dict.fromkeys(keys))
        t_start = time.perf_counter()
        res = self.residency
        to_load: list[str] = []
        wait_for: list[tuple[str, str]] = []  # (key, in-flight loader source)
        cold: list[str] = []  # not RESIDENT at demand time (trace faults)
        with self._lock:
            res.advance_clock()  # one stamp per ensure batch
            for k in keys:
                st = res.state_of(k)
                if st == RESIDENT:
                    if res.touch(k) == "prefetch":
                        self.stats.prefetch_hits += 1
                    else:
                        self.stats.hits += 1
                elif st == LOADING:
                    cold.append(k)
                    wait_for.append((k, res.loader_of(k)))
                else:
                    cold.append(k)
                    if res.begin_load(k, source):
                        to_load.append(k)
            if pin:
                res.pin(keys)
            if self.trace is not None and source == "fault":
                self.trace.record(keys, cold, self._phase)
        if not to_load and not wait_for:
            return 0

        moved = 0
        if to_load:
            if self.store is None:
                with self._lock:
                    for k in to_load:
                        res.abort_load(k)
                raise RuntimeError(
                    f"tier-1 units {to_load[:3]}... required but no optional store attached"
                )
            ordered = sorted(to_load, key=lambda k: self.store.entries[k].offset)
            # vectored fault-in (DESIGN.md §17.2): one coalesced read pass
            # per chunk, then decode+install per key. Chunking bounds the
            # compressed bytes held at once to ~a chunk's worth while still
            # letting manifest-adjacent frames share preads.
            CHUNK = 32
            for base in range(0, len(ordered), CHUNK):
                chunk = ordered[base:base + CHUNK]
                try:
                    tr0 = time.perf_counter()
                    rs = ReadStats()
                    bufs = self.store.read_raw_many(chunk, stats=rs)
                    t_read = time.perf_counter() - tr0
                except Exception:
                    with self._lock:
                        # roll back every not-yet-loaded claim, or they'd
                        # sit in LOADING with no loader forever
                        for k in ordered[base:]:
                            res.abort_load(k)
                    raise
                self.stats.preads_issued += rs.preads
                self.stats.frames_fetched += rs.frames
                self.stats.coalesced_bytes += rs.coalesced_bytes
                total_csize = sum(
                    self.store.entries[k].csize for k in chunk) or 1
                for j, key in enumerate(chunk):
                    try:
                        t0 = time.perf_counter()
                        arr = self.store.decode(key, bufs[key])  # no lock
                        t1 = time.perf_counter()
                    except Exception:
                        with self._lock:
                            for k in ordered[base + j:]:
                                res.abort_load(k)
                        raise
                    # amortize the chunk's read wall csize-proportionally so
                    # per-event fetch_s still sums to time actually spent
                    fetch_s = (t1 - t0) + t_read * (
                        self.store.entries[key].csize / total_csize)
                    charge = self.unit_charge(key, arr.nbytes)
                    if self.arbiter is not None:
                        # cross-tenant make-room BEFORE taking our own lock
                        # (arbiter lock orders first; it may lock other tenants)
                        self.arbiter.make_room(self, charge)
                    with self._lock:
                        self._evict_to_fit(charge)
                        self._install(self._all_units[key], arr)
                        t2 = time.perf_counter()
                        res.commit_load(key, charge, source)
                        if res.was_evicted(key):
                            self.stats.refaults += 1
                        if source == "fault":  # preload is not a request-path miss
                            self.stats.misses += 1
                        self.stats.events.append(
                            LoadEvent(key, arr.nbytes, fetch_s, t2 - t1,
                                      t=time.monotonic(), source=source,
                                      phase=self._phase)
                        )
                    moved += arr.nbytes

        if wait_for:
            with self._lock:
                for k, loader in wait_for:
                    while not res.is_resident(k):
                        if res.begin_load(k, source):
                            # the other loader aborted — take over synchronously
                            self._lock.release()
                            try:
                                moved += self._load_one(k, source)
                            finally:
                                self._lock.acquire()
                            break
                        if not res.wait_resident(k) and res.state_of(k) == LOADING:
                            # never return with the key silently cold — the
                            # caller would compute on placeholder zeros
                            raise RuntimeError(
                                f"timed out waiting for in-flight load of {k!r}"
                            )
                        # COLD after an abort: loop back and try to claim
                    else:
                        res.touch(k)
                        if loader == "prefetch":
                            self.stats.prefetch_waits += 1
                        # a sibling demand load already counted its miss
        if source == "fault":  # miss-stall percentiles are request-path only
            self.stats.stalls.append(time.perf_counter() - t_start)
        return moved

    def _load_one(self, key: str, source: str) -> int:
        """Synchronous load of one already-claimed key (takeover path)."""
        res = self.residency
        try:
            t0 = time.perf_counter()
            rs = ReadStats()
            arr = self.store.decode(key, self.store.read_raw(key, stats=rs))
            t1 = time.perf_counter()
        except Exception:
            with self._lock:
                res.abort_load(key)
            raise
        self.stats.preads_issued += rs.preads
        self.stats.frames_fetched += rs.frames
        charge = self.unit_charge(key, arr.nbytes)
        if self.arbiter is not None:
            self.arbiter.make_room(self, charge)
        with self._lock:
            self._evict_to_fit(charge)
            self._install(self._all_units[key], arr)
            t2 = time.perf_counter()
            res.commit_load(key, charge, source)
            if source == "fault":
                self.stats.misses += 1
            self.stats.events.append(
                LoadEvent(key, arr.nbytes, t1 - t0, t2 - t1,
                          t=time.monotonic(), source=source,
                          phase=self._phase)
            )
        return arr.nbytes

    def ensure_all(self) -> int:
        """Load every tier-1 unit (degrades to the 'full' baseline)."""
        return self.ensure(list(self._all_units))

    def touch(self, keys: Iterable[str]) -> None:
        """Refresh LRU recency without demand-access accounting (used by
        predictive hints on already-resident units)."""
        with self._lock:
            self.residency.advance_clock()
            for k in keys:
                self.residency.touch(k, claim_prefetch=False)

    def release(self, keys: Iterable[str]) -> None:
        """Unpin keys pinned by ``ensure(pin=True)`` — they become
        evictable again once every pin is released. If pinned installs
        overshot the budget, the excess is reclaimed here (LRU first), so
        over-budget residency never outlives the step that forced it."""
        with self._lock:
            self.residency.release(keys)
            self._evict_to_budget()
        if self.arbiter is not None:
            # host-level reclaim happens outside our lock (lock ordering:
            # the arbiter may need to lock other tenants)
            self.arbiter.rebalance()

    def _evict_to_budget(self) -> None:
        """Evict LRU unpinned units until resident bytes fit the budget.
        Caller holds the lock."""
        res = self.residency
        if res.budget_bytes is None:
            return
        need = res.resident_bytes - res.budget_bytes
        if need <= 0:
            return
        for k in res.select_victims(need):
            self._evict_one(k)

    # -- prefetch integration (DESIGN.md §8.2) -------------------------------
    def claim_for_prefetch(self, key: str) -> bool:
        """COLD → LOADING on behalf of the prefetcher's reader thread."""
        if key not in self._all_units:
            return False
        with self._lock:
            return self.residency.begin_load(key, "prefetch")

    def abort_prefetch(self, key: str) -> None:
        with self._lock:
            self.residency.abort_load(key)

    def install_prefetched(self, key: str, arr: np.ndarray, fetch_s: float = 0.0) -> int:
        """Upload one staged host array claimed via ``claim_for_prefetch``.

        The host-side dtype conversion/copy happens *before* taking the
        shared lock (leaf dtypes are fixed at allocation), so request-path
        ``ensure()`` calls are not serialized behind the bulk of the
        background upload work.
        """
        unit = self._all_units.get(key)
        if unit is None or self.residency.state_of(key) != LOADING:
            return 0
        nbytes = arr.nbytes
        charge = self.unit_charge(key, nbytes)
        host = jnp.asarray(arr, dtype=self._flat[unit.path].dtype)
        if self.arbiter is not None:
            self.arbiter.make_room(self, charge)
        with self._lock:
            if self.residency.state_of(key) != LOADING:
                return 0
            self.residency.advance_clock()
            self._evict_to_fit(charge)
            t0 = time.perf_counter()
            self._install(unit, host)
            upload_s = time.perf_counter() - t0
            self.residency.commit_load(key, charge, "prefetch")
            self.stats.events.append(
                LoadEvent(key, nbytes, fetch_s, upload_s,
                          t=time.monotonic(), source="prefetch",
                          phase=self._phase)
            )
        return nbytes

    # -- eviction -------------------------------------------------------------
    def _evict_to_fit(self, incoming_nbytes: int) -> None:
        """Evict LRU unpinned units until the incoming bytes fit the budget.
        Caller holds the lock. If nothing is evictable the install proceeds
        (correctness over budget) and the overshoot is counted."""
        res = self.residency
        budget = res.budget_bytes
        if budget is None:
            return
        need = res.resident_bytes + incoming_nbytes - budget
        if need <= 0:
            return
        for k in res.select_victims(need):
            self._evict_one(k)
        if res.resident_bytes + incoming_nbytes > budget:
            res.overshoot_events += 1

    def _evict_one(self, key: str) -> int:
        """Reinstall the placeholder for one RESIDENT unpinned unit."""
        unit = self._all_units[key]
        self._install_placeholder(unit)
        nb = self.residency.evict_commit(key)
        self.stats.evictions += 1
        self.stats.evicted_bytes += nb
        return nb

    def evict(self, keys: Iterable[str]) -> int:
        """Explicitly evict resident, unpinned units. Returns bytes freed."""
        freed = 0
        with self._lock:
            for k in keys:
                if self.residency.is_resident(k) and self.residency.pins_of(k) == 0:
                    freed += self._evict_one(k)
        return freed

    def eviction_candidates(self) -> list:
        """Locked snapshot of this instance's evictable pool for the host
        arbiter's global victim pass (DESIGN.md §13.1): ``(key, nbytes,
        stamp)`` for every RESIDENT, unpinned unit, oldest stamp first.
        LOADING and pinned keys are structurally absent; the arbiter's
        subsequent ``evict()`` re-validates under the lock anyway (the
        snapshot may race a pin)."""
        with self._lock:
            res = self.residency
            return [
                (k, res._nbytes.get(k, 0), res._stamp.get(k, 0))
                for k in res._lru
                if res.pins_of(k) == 0
            ]

    # -- installation --------------------------------------------------------
    def _install(self, unit: Unit, arr: np.ndarray) -> None:
        leaf = self._flat[unit.path]
        host = jnp.asarray(arr, dtype=leaf.dtype)
        if not unit.sel and unit.rows is None:
            new = jax.device_put(host, self._leaf_sharding(leaf))
        elif unit.rows is not None:
            lo, hi = unit.rows
            new = leaf.at[unit.sel + (slice(lo, hi),)].set(host) if unit.sel else leaf.at[lo:hi].set(host)
        else:  # (layer,) expert slice
            new = leaf.at[unit.sel].set(host)
        self._set_leaf(unit.path, new)

    def _install_placeholder(self, unit: Unit) -> None:
        """The eviction inverse of ``_install``: zero the unit's slice."""
        leaf = self._flat[unit.path]
        if not unit.sel and unit.rows is None:
            new = jax.device_put(jnp.zeros(leaf.shape, leaf.dtype), self._leaf_sharding(leaf))
        elif unit.rows is not None:
            lo, hi = unit.rows
            new = leaf.at[unit.sel + (slice(lo, hi),)].set(0) if unit.sel else leaf.at[lo:hi].set(0)
        else:
            new = leaf.at[unit.sel].set(0)
        self._set_leaf(unit.path, new)

    def _leaf_sharding(self, leaf):
        try:
            return leaf.sharding
        except Exception:
            return None

    def _set_leaf(self, path: str, new) -> None:
        self._flat[path] = new
        node = self._tree
        parts = path.split(".")
        for p in parts[:-1]:
            node = node[p]
        node[parts[-1]] = new

    # -- access ----------------------------------------------------------------
    def tree(self) -> dict:
        return self._tree

    def leaf(self, path: str):
        return self._flat[path]


def placeholder_tree(abstract: Any, tier0: dict[str, np.ndarray], plan: TierPlan, put: Callable) -> dict:
    """Build the initial live tree: tier-0 leaves from real weights, tier-1
    leaves as placeholder zeros (identical shapes/shardings → identical
    compiled executable; the paper's rewritten function with an empty body).

    ``put(path, host_array_or_none, leaf_spec)`` -> device array; the
    cold-start manager passes a sharded device_put.
    """
    out: dict[str, Any] = {}
    for path, leaf in flatten_with_paths(abstract):
        if plan.decisions[path].tier == 0:
            out[path] = put(path, tier0[path], leaf)
        else:
            out[path] = put(path, None, leaf)
    return tree_from_flat(out)
