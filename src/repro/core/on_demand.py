"""④ On-demand loading — the ``rewrite_template`` analogue.

The paper rewrites each optional function to a 2-line stub that, on first
invocation, reads the lightweight file, materializes the separated code, and
executes it. Here the "stub" is a *placeholder buffer*: tier-1 leaves start
as zero-filled device arrays (correctly sharded, so the compiled executable
is identical to the fully-loaded one); the ``OnDemandLoader`` faults real
bytes in unit-by-unit when requests need them.

Correctness backstop, as in the paper: a misprediction (cold expert routed
to, cold vocab row sampled) is a *latency* event — fetch + decompress +
device upload + row scatter — never a failure. ``ensure()`` is idempotent
and thread-safe; the loaded-set survives for the life of the process (the
paper's "one-time cost per container").
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optional_store import OptionalStore
from repro.core.partition import TierPlan, Unit
from repro.utils.tree import flatten_with_paths, tree_from_flat


@dataclass
class LoadEvent:
    key: str
    nbytes: int
    fetch_s: float
    upload_s: float


@dataclass
class LoaderStats:
    events: list = field(default_factory=list)
    misses: int = 0
    hits: int = 0

    @property
    def total_miss_bytes(self) -> int:
        return sum(e.nbytes for e in self.events)

    @property
    def total_miss_s(self) -> float:
        return sum(e.fetch_s + e.upload_s for e in self.events)


class TieredParams:
    """The live parameter tree of a cold-started server.

    * tier-0 leaves: real weights, device-resident from cold start.
    * tier-1 leaves: allocated at full shape (placeholder zeros) and filled
      in-place per unit (experts: ``at[e].set``; rows: row-slice scatter;
      whole-leaf: swap). Allocation is eager but *bytes* move lazily —
      device memory for tier-1 is the explicit rent paid for the identical
      executable; strict deployments can zero-page it.

    ``tree()`` returns the current param pytree to pass into compiled fns.
    """

    def __init__(self, tree: dict, plan: TierPlan, store: Optional[OptionalStore]):
        self._tree = tree
        self._flat = dict(flatten_with_paths(tree))
        self.plan = plan
        self.store = store
        self.stats = LoaderStats()
        self._resident: set[str] = set()
        self._lock = threading.RLock()
        # placeholder-resident units: every tier-1 unit starts cold except
        # the plan's preloaded hot set (loaded by the cold-start manager).
        self._all_units: dict[str, Unit] = {}
        for d in plan.decisions.values():
            for u in d.units:
                self._all_units[u.key] = u

    # -- residency ----------------------------------------------------------
    def is_resident(self, key: str) -> bool:
        return key in self._resident

    def mark_resident(self, key: str) -> None:
        self._resident.add(key)

    @property
    def resident_keys(self) -> set:
        return set(self._resident)

    def resident_fraction(self) -> float:
        n = len(self._all_units)
        return len(self._resident) / n if n else 1.0

    # -- the rewrite_template analogue ---------------------------------------
    def ensure(self, keys: Iterable[str]) -> int:
        """Fault in the given unit keys. Returns bytes moved (0 = warm hit).

        This is the two-line stub body: check residency, fetch on miss.
        """
        moved = 0
        with self._lock:
            miss = [k for k in keys if k not in self._resident]
            if not miss:
                self.stats.hits += len(list(keys)) if not isinstance(keys, (list, tuple, set)) else len(keys)
                return 0
            if self.store is None:
                raise RuntimeError(
                    f"tier-1 units {miss[:3]}... required but no optional store attached"
                )
            for key in sorted(miss, key=lambda k: self.store.entries[k].offset):
                t0 = time.perf_counter()
                arr = self.store.fetch(key)
                t1 = time.perf_counter()
                self._install(self._all_units[key], arr)
                t2 = time.perf_counter()
                self._resident.add(key)
                self.stats.misses += 1
                self.stats.events.append(LoadEvent(key, arr.nbytes, t1 - t0, t2 - t1))
                moved += arr.nbytes
        return moved

    def ensure_all(self) -> int:
        """Load every tier-1 unit (degrades to the 'full' baseline)."""
        return self.ensure(list(self._all_units))

    # -- installation --------------------------------------------------------
    def _install(self, unit: Unit, arr: np.ndarray) -> None:
        leaf = self._flat[unit.path]
        host = jnp.asarray(arr, dtype=leaf.dtype)
        if not unit.sel and unit.rows is None:
            new = jax.device_put(host, self._leaf_sharding(leaf))
        elif unit.rows is not None:
            lo, hi = unit.rows
            new = leaf.at[unit.sel + (slice(lo, hi),)].set(host) if unit.sel else leaf.at[lo:hi].set(host)
        else:  # (layer,) expert slice
            new = leaf.at[unit.sel].set(host)
        self._set_leaf(unit.path, new)

    def _leaf_sharding(self, leaf):
        try:
            return leaf.sharding
        except Exception:
            return None

    def _set_leaf(self, path: str, new) -> None:
        self._flat[path] = new
        node = self._tree
        parts = path.split(".")
        for p in parts[:-1]:
            node = node[p]
        node[parts[-1]] = new

    # -- access ----------------------------------------------------------------
    def tree(self) -> dict:
        return self._tree

    def leaf(self, path: str):
        return self._flat[path]


def placeholder_tree(abstract: Any, tier0: dict[str, np.ndarray], plan: TierPlan, put: Callable) -> dict:
    """Build the initial live tree: tier-0 leaves from real weights, tier-1
    leaves as placeholder zeros (identical shapes/shardings → identical
    compiled executable; the paper's rewritten function with an empty body).

    ``put(path, host_array_or_none, leaf_spec)`` -> device array; the
    cold-start manager passes a sharded device_put.
    """
    out: dict[str, Any] = {}
    for path, leaf in flatten_with_paths(abstract):
        if plan.decisions[path].tier == 0:
            out[path] = put(path, tier0[path], leaf)
        else:
            out[path] = put(path, None, leaf)
    return tree_from_flat(out)
