"""⑧ Host-level residency arbiter — N models under one device budget
(DESIGN.md §13).

FaaSLight's density story is many functions packed on one host, each
loading only indispensable code; per-host function density is the primary
driver of cold-start frequency, and the latency floor is set by what must
be re-loaded when co-tenants steal memory. Until this layer, the
device-bytes budget was *per-`TieredParams`* — each model policed itself
and knew nothing about its neighbours. The ``HostArbiter`` inverts that
ownership: ONE host-wide budget, N registered tenants, and every
make-room decision is made globally:

    register(name, tiered, share, floor)  ── tenant joins the host pool;
        its private budget is disabled (restored at unregister)
    make_room(requester, incoming)        ── called by a tenant's install
        path BEFORE it takes its own lock; victims are chosen across ALL
        tenants
    rebalance()                           ── called after pin releases and
        by the re-tiering daemon; reclaims any transient overshoot

**Victim rule** (DESIGN.md §13.1): candidates are every tenant's
RESIDENT, unpinned units (LOADING and pinned keys of *every* tenant are
structurally excluded — selection goes through each tenant's own locked
``eviction_candidates``/``evict`` API, which enforces the §8.1 rules).
Candidates are ranked coldest-first by

    (heat(key) x normalized_share, -utilization, tenant, lru_stamp, key)

where ``heat`` is the decayed trace-derived touch count (the live
``AccessTrace`` window plus the daemon's decay-merged history when one is
attached), so a tenant with a larger *share* keeps its units looking
hotter, and among heat ties the most over-its-fair-share tenant
(``utilization = resident / share_bytes``) loses first, oldest unit
first. A per-tenant ``floor_bytes`` is never crossed: one hot model can
squeeze its neighbours down to their floors but can never fully starve
them (the floors must fit inside the budget — ``register`` validates).

**Share feedback** (DESIGN.md §13.2): the ``RetierDaemon`` feeds each
tick's observed refault and overshoot deltas back via ``observe_tick``;
shares drift toward the pressure-proportional split (bounded, decayed,
renormalized so the total never changes), so a model that is thrashing
under its slice grows it at the expense of comfortable co-tenants.

**Locking discipline**: the arbiter lock is ordered BEFORE every tenant
lock — arbiter entry points are only ever called with *no* tenant lock
held (``TieredParams`` calls ``make_room`` before acquiring its own lock
and ``rebalance`` after releasing it), and no code path acquires the
arbiter lock while holding a tenant lock. Holding the arbiter lock
across a global eviction serializes concurrent make-room storms, which
is exactly the property the cross-tenant stress test relies on for exact
byte bookkeeping (tests/test_arbiter.py).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional

from repro.core.on_demand import AccessTrace, TieredParams

# decayed pressure below this is stale noise, not demand: zero it so the
# share split can relax back to the registration baseline
_RATE_FLOOR = 1e-2


@dataclass
class HostArbiterStats:
    """Lifetime accounting (asserted by tests and bench_rq9_zoo)."""

    registered: int = 0
    unregistered: int = 0
    rebalances: int = 0        # make_room/rebalance calls that had work to do
    evictions: int = 0         # victims the arbiter evicted (all tenants)
    evicted_bytes: int = 0
    cross_evictions: int = 0   # victim owner != requesting tenant
    overshoots: int = 0        # make-room calls that could not free enough
    floor_skips: int = 0       # candidates passed over to respect a floor
    share_updates: int = 0     # feedback-driven share retunings
    headroom_denials: int = 0  # speculative prefetch gates closed

    def to_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass
class Tenant:
    """One registered model instance under the host budget."""

    name: str
    tiered: TieredParams
    share: float               # relative budget weight (feedback-tunable)
    base_share: float          # the registration share; shares drift back
    floor_bytes: int           # arbiter eviction never crosses this
    saved_budget: Optional[int]  # tenant's private budget, restored at exit
    history: Optional[AccessTrace] = None  # daemon's decay-merged heat
    overshoots: int = 0        # make-room shortfalls charged to this tenant
    last_refaults: int = 0     # feedback deltas (observe_tick)
    last_overshoots: int = 0
    refault_rate: float = 0.0  # decayed per-tick rates
    overshoot_rate: float = 0.0


class HostArbiter:
    """One host-wide device-bytes budget shared by N ``TieredParams``.

    See the module docstring for the victim rule, share feedback, and the
    lock-ordering contract. All public methods are thread-safe and must
    be called with no tenant lock held.
    """

    def __init__(
        self,
        budget_bytes: int,
        *,
        feedback_gain: float = 0.2,
        feedback_decay: float = 0.5,
        min_share_frac: float = 0.05,
    ):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be positive, got {budget_bytes}")
        if not 0.0 <= feedback_gain <= 1.0:
            raise ValueError(f"feedback_gain must be in [0, 1], got {feedback_gain!r}")
        if not 0.0 <= feedback_decay <= 1.0:
            raise ValueError(f"feedback_decay must be in [0, 1], got {feedback_decay!r}")
        self.budget_bytes = budget_bytes
        self.feedback_gain = feedback_gain
        self.feedback_decay = feedback_decay
        self.min_share_frac = min_share_frac
        self.stats = HostArbiterStats()
        self._lock = threading.RLock()
        self._tenants: dict[str, Tenant] = {}
        self._by_id: dict[int, Tenant] = {}  # id(tiered) -> Tenant

    # -- registry ---------------------------------------------------------------
    def register(
        self,
        name: str,
        tiered: TieredParams,
        *,
        share: float = 1.0,
        floor_bytes: int = 0,
    ) -> Tenant:
        """Adopt one ``TieredParams`` into the host pool.

        The tenant's private ``budget_bytes`` is disabled (its own
        ``_evict_to_fit``/``_evict_to_budget`` become no-ops) and every
        install/release on it routes through this arbiter instead — the
        ownership inversion. Restored by ``unregister``.
        """
        if share <= 0:
            raise ValueError(f"share must be positive, got {share!r}")
        if floor_bytes < 0:
            raise ValueError(f"floor_bytes must be >= 0, got {floor_bytes}")
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            if tiered.arbiter is not None:
                raise ValueError(
                    f"TieredParams already governed by an arbiter "
                    f"(tenant {tiered.tenant_name!r})"
                )
            floors = sum(t.floor_bytes for t in self._tenants.values()) + floor_bytes
            if floors > self.budget_bytes:
                raise ValueError(
                    f"per-tenant floors ({floors}B) exceed the host budget "
                    f"({self.budget_bytes}B) — floors must be jointly satisfiable"
                )
            tenant = Tenant(
                name=name,
                tiered=tiered,
                share=share,
                base_share=share,
                floor_bytes=floor_bytes,
                saved_budget=tiered.residency.budget_bytes,
            )
            self._tenants[name] = tenant
            self._by_id[id(tiered)] = tenant
            tiered.residency.budget_bytes = None  # host governance from here on
            tiered.arbiter = self
            tiered.tenant_name = name
            self.stats.registered += 1
            return tenant

    def unregister(self, name: str) -> None:
        """Detach a tenant: its private budget is restored and its bytes
        stop counting against the host. Resident units stay resident —
        the tenant's own ``_evict_to_budget`` reclaims any excess on its
        next release."""
        with self._lock:
            tenant = self._tenants.pop(name, None)
            if tenant is None:
                raise KeyError(f"unknown tenant {name!r}")
            self._by_id.pop(id(tenant.tiered), None)
            tenant.tiered.arbiter = None
            tenant.tiered.tenant_name = ""
            tenant.tiered.residency.budget_bytes = tenant.saved_budget
            self.stats.unregistered += 1

    @property
    def tenants(self) -> dict:
        with self._lock:
            return dict(self._tenants)

    def tenant_of(self, tiered: TieredParams) -> Optional[Tenant]:
        with self._lock:
            return self._by_id.get(id(tiered))

    # -- queries ----------------------------------------------------------------
    def total_resident_bytes(self) -> int:
        with self._lock:
            return sum(t.tiered.resident_bytes for t in self._tenants.values())

    def shares(self) -> dict:
        with self._lock:
            return {n: t.share for n, t in self._tenants.items()}

    def share_bytes(self, name: str) -> int:
        """A tenant's share-resolved slice of the host budget (informational
        — shares weight the victim rule; they are not hard partitions)."""
        with self._lock:
            return self._share_bytes(self._tenants[name])

    def _share_bytes(self, tenant: Tenant) -> int:
        total = sum(t.share for t in self._tenants.values())
        return int(self.budget_bytes * tenant.share / total) if total else 0

    # -- the cross-model make-room path ----------------------------------------
    def make_room(self, requester: Optional[TieredParams], incoming_nbytes: int) -> int:
        """Free host budget for ``incoming_nbytes`` about to land in
        ``requester`` (None = pure rebalance). MUST be called with no
        tenant lock held. Victims are chosen across every tenant by the
        §13.1 rule; returns bytes actually freed. If pins + floors make
        the target unreachable the shortfall is recorded (host overshoot
        + the requesting tenant's feedback counter) and the install
        proceeds anyway — correctness over budget, exactly as in the
        single-tenant state machine (§8.1)."""
        with self._lock:
            need = (
                sum(t.tiered.resident_bytes for t in self._tenants.values())
                + incoming_nbytes
                - self.budget_bytes
            )
            if need <= 0:
                return 0
            self.stats.rebalances += 1
            freed = self._evict_global(need, requester)
            if freed < need:
                self.stats.overshoots += 1
                if requester is not None:
                    t = self._by_id.get(id(requester))
                    if t is not None:
                        t.overshoots += 1
            return freed

    def rebalance(self) -> int:
        """Reclaim any transient overshoot (called after pin releases and
        by daemon ticks). Cheap when the host is already under budget."""
        return self.make_room(None, 0)

    def _evict_global(self, need: int, requester: Optional[TieredParams]) -> int:
        """One coldest-first pass over every tenant's evictable units.
        Caller holds the arbiter lock (and no tenant lock)."""
        total_share = sum(t.share for t in self._tenants.values()) or 1.0
        cands: list[tuple[tuple, Tenant, str, int]] = []
        floor_room: dict[str, int] = {}
        for t in self._tenants.values():
            share_b = max(1, self._share_bytes(t))
            resident = t.tiered.resident_bytes
            floor_room[t.name] = resident - t.floor_bytes
            utilization = resident / share_b
            heat = self._heat(t)
            norm_share = t.share / total_share
            for key, nbytes, stamp in t.tiered.eviction_candidates():
                score = (heat.get(key, 0) * norm_share, -utilization,
                         t.name, stamp, key)
                cands.append((score, t, key, nbytes))
        cands.sort(key=lambda c: c[0])

        freed = 0
        for _, t, key, nbytes in cands:
            if freed >= need:
                break
            if floor_room[t.name] - nbytes < 0:
                self.stats.floor_skips += 1
                continue
            got = t.tiered.evict([key])  # re-checks pinned/LOADING under t's lock
            if not got:
                continue  # raced: pinned or evicted since the snapshot
            floor_room[t.name] -= got
            freed += got
            self.stats.evictions += 1
            self.stats.evicted_bytes += got
            if requester is not None and t.tiered is not requester:
                self.stats.cross_evictions += 1
        return freed

    def _heat(self, tenant: Tenant) -> dict:
        """Decayed trace-derived touch counts: the daemon's decay-merged
        history (when attached via ``note_trace``) plus the live window."""
        heat: dict = {}
        if tenant.history is not None:
            heat.update(tenant.history.touches)
        snap = tenant.tiered.trace_snapshot()  # locked copy; None if tracing off
        if snap is not None:
            for k, v in snap.touches.items():
                heat[k] = heat.get(k, 0) + v
        return heat

    # -- daemon feedback (DESIGN.md §13.2) --------------------------------------
    def note_trace(self, tiered: TieredParams, merged: Optional[AccessTrace]) -> None:
        """Hand the arbiter a tenant's decay-merged trace history — the
        daemon calls this each tick so victim selection sees decayed heat
        even after the live window was rotated away."""
        with self._lock:
            t = self._by_id.get(id(tiered))
            if t is not None:
                t.history = merged

    def observe_tick(self, tiered: TieredParams) -> None:
        """Fold one daemon tick's observed refault/overshoot deltas into
        the tenant's decayed pressure rates, then retune shares toward the
        pressure-proportional split (bounded below by ``min_share_frac``
        of the total, renormalized so the share sum never changes)."""
        with self._lock:
            t = self._by_id.get(id(tiered))
            if t is None:
                return
            refaults = t.tiered.stats.refaults
            d_refault = refaults - t.last_refaults
            t.last_refaults = refaults
            d_over = t.overshoots - t.last_overshoots
            t.last_overshoots = t.overshoots
            t.refault_rate = self.feedback_decay * t.refault_rate + d_refault
            t.overshoot_rate = self.feedback_decay * t.overshoot_rate + d_over
            # geometric decay never reaches zero on its own: floor stale
            # pressure so quiet tenants stop steering the split
            if t.refault_rate < _RATE_FLOOR:
                t.refault_rate = 0.0
            if t.overshoot_rate < _RATE_FLOOR:
                t.overshoot_rate = 0.0
            self._retune_shares()

    def _retune_shares(self) -> None:
        tenants = list(self._tenants.values())
        if len(tenants) < 2:
            return
        pressure = {t.name: t.refault_rate + t.overshoot_rate for t in tenants}
        total_p = sum(pressure.values())
        total_share = sum(t.share for t in tenants)
        gain = self.feedback_gain
        lo = self.min_share_frac * total_share
        if total_p <= 0:
            # at rest the split relaxes back to the registration shares
            if all(t.share == t.base_share for t in tenants):
                return
            for t in tenants:
                t.share = max(lo, (1.0 - gain) * t.share + gain * t.base_share)
        else:
            for t in tenants:
                target = (pressure[t.name] / total_p) * total_share
                t.share = max(lo, (1.0 - gain) * t.share + gain * target)
        scale = total_share / sum(t.share for t in tenants)
        for t in tenants:
            t.share *= scale
        self.stats.share_updates += 1

    # -- speculative-load gate ---------------------------------------------------
    def prefetch_headroom(self, tiered: TieredParams, nbytes: int = 0) -> bool:
        """Should a *speculative* load for this tenant proceed? True while
        the host has free budget, or while the tenant sits under its
        share-resolved slice (its installs then displace over-share
        co-tenants, which is the victim rule working as intended). False
        means a prefetch would force evictions purely to stage a guess —
        the ``Prefetcher`` drops the hint instead (DESIGN.md §13.1)."""
        with self._lock:
            t = self._by_id.get(id(tiered))
            if t is None:
                return True
            total = sum(x.tiered.resident_bytes for x in self._tenants.values())
            if total + nbytes <= self.budget_bytes:
                return True
            ok = tiered.resident_bytes + nbytes <= self._share_bytes(t)
            if not ok:
                self.stats.headroom_denials += 1
            return ok

    # -- audit -------------------------------------------------------------------
    def audit(self) -> dict:
        """Cross-check every tenant's byte bookkeeping (charged bytes ==
        sum of per-key charges over the resident set) and report host
        totals. Raises AssertionError on any inconsistency — the property
        and stress tests call this after every settling point."""
        with self._lock:
            total = 0
            pinned = 0
            per_tenant = {}
            for t in self._tenants.values():
                tp = t.tiered
                with tp._lock:
                    res = tp.residency
                    charged = res.charged_bytes()
                    assert charged == res.resident_bytes, (
                        f"{t.name}: charged {charged} != accounted {res.resident_bytes}"
                    )
                    pb = sum(
                        res._nbytes.get(k, 0)
                        for k in res._lru
                        if res.pins_of(k) > 0
                    )
                total += charged
                pinned += pb
                per_tenant[t.name] = {
                    "resident_bytes": charged,
                    "pinned_bytes": pb,
                    "floor_bytes": t.floor_bytes,
                    "share": t.share,
                }
            return {
                "budget_bytes": self.budget_bytes,
                "resident_bytes": total,
                "pinned_bytes": pinned,
                "over_budget": max(0, total - self.budget_bytes),
                "tenants": per_tenant,
            }
