"""⑤ Predictive prefetch — hiding the one-time fault latency (DESIGN.md §8.2).

FaaSLight makes a misprediction a latency event instead of a failure; the
profile-guided follow-up (arXiv:2504.19283) shows that *predictively*
loading the deferred tail hides most of that latency. The ``Prefetcher``
consumes access hints from the serving engine (router usage masks, top-k
vocab candidates from the last decoded logits) and pulls tier-1 units from
the ``OptionalStore`` off the request path:

    hint(keys) ──▶ [hint set] ──reader thread──▶ fetch+decompress (host)
                                  │ bounded, double-buffered staging
                                  ▼
                   [stage queue] ──uploader thread──▶ device install

Two threads pipeline the work: the *reader* does pread + zlib decompress
(both release the GIL) into host staging buffers, while the *uploader*
drains staged buffers into the device via ``TieredParams.install_prefetched``.
The stage queue is bounded (default two buffers — classic double
buffering), so a slow device never lets host staging grow without bound,
and decompress of batch N+1 overlaps upload of batch N, which overlaps the
model's own compute on the request thread.

Claim protocol (the "eviction never races an in-flight read" invariant):
the reader claims each key COLD→LOADING via ``claim_for_prefetch`` before
touching the store; a demand ``ensure()`` that wants a claimed key waits on
the residency condition instead of reading twice, and eviction never
selects a LOADING unit. On shutdown every unfinished claim is aborted back
to COLD so no waiter hangs.

Predictive mode (DESIGN.md §11.3): with a ``TransitionPredictor`` attached
(built from a profiling run's ``AccessTrace``), ``observe(keys)`` expands
each step's *actual* demand accesses into their learned successors and
hints them immediately — one step ahead of the engine's own logits/routing
hints, which can only name units the current step already points at.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from repro.core.on_demand import TieredParams
from repro.core.optional_store import COALESCE_GAP, ReadStats, StoreError


def merge_hints(*hint_lists: Iterable[str]) -> list[str]:
    """Round-robin-merge per-slot hint lists into one deduped FIFO stream.

    The scheduler collects hints per active slot (each slot's list is
    ordered most-likely-first); a plain concatenation would let slot 0's
    long tail starve every other slot's best predictions, because the
    Prefetcher drains its hint set oldest-first. Interleaving
    (slot0[0], slot1[0], …, slot0[1], slot1[1], …) keeps the prefetch
    bandwidth fair across concurrent requests."""
    out: "OrderedDict[str, None]" = OrderedDict()
    iters = [iter(h) for h in hint_lists]
    while iters:
        survivors = []
        for it in iters:
            for k in it:
                out.setdefault(k, None)
                survivors.append(it)
                break
        iters = survivors
    return list(out)


def _rank(counts: dict, k: int) -> list[str]:
    """Top-``k`` keys by observed count, equal counts tie-broken by key —
    NEVER by dict insertion order, so an identical table built from a
    differently-ordered trace predicts in an identical order
    (tests/test_fleet.py regression)."""
    return [n for n, _ in sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))[:k]]


class TransitionPredictor:
    """Learned unit→next-unit model from a profiling run (DESIGN.md §11.3,
    upgraded per §14.2).

    Three stacked signals, consulted most-specific-first by ``follow``:

      * **second-order** — ``AccessTrace.transitions2``: successors of the
        *(two-batches-ago, previous-batch)* unit pair; a workload whose
        step t is ambiguous given step t−1 alone (shared prefix, divergent
        tails) disambiguates on the pair;
      * **phase-conditioned** — ``AccessTrace.phase_transitions``: separate
        successor tables for prefill and decode batches (a unit hot during
        prefill is often cold in decode); falls back to
      * **first-order global** — the original ``transitions`` table.

    Rankings come from observed counts with ties broken by key (see
    ``_rank``); per-key lists are round-robin-merged (``merge_hints``, the
    scheduler's per-slot fairness rule) so one unit's long tail cannot
    starve another's best prediction. Finally each predicted unit is
    **cluster-expanded** through its strongest co-access mates (from the
    coincidence-free ``request_pairs`` when present, else ``pairs``): one
    predicted hit pre-warms the whole cluster that historically loads
    together.
    """

    def __init__(
        self,
        transitions: dict,
        *,
        top_k: int = 8,
        phase_transitions: Optional[dict] = None,
        transitions2: Optional[dict] = None,
        pairs: Optional[dict] = None,
        cluster_size: int = 3,
        cluster_min_count: int = 2,
    ):
        self.top_k = max(1, top_k)
        self._table: dict[str, list[str]] = {
            key: _rank(counts, self.top_k)
            for key, counts in transitions.items()
            if counts
        }
        self._phase_tables: dict[str, dict[str, list[str]]] = {
            ph: {key: _rank(counts, self.top_k) for key, counts in tbl.items() if counts}
            for ph, tbl in (phase_transitions or {}).items()
        }
        self._table2: dict[tuple, list[str]] = {
            ctx: _rank(counts, self.top_k)
            for ctx, counts in (transitions2 or {}).items()
            if counts
        }
        # co-access clusters as bounded neighbour lists: for each unit, its
        # ``cluster_size`` strongest partners with pair count >=
        # ``cluster_min_count`` (a one-off coincidence is not a cluster)
        by_key: dict[str, dict[str, int]] = {}
        for (a, b), n in (pairs or {}).items():
            if n >= cluster_min_count:
                by_key.setdefault(a, {})[b] = n
                by_key.setdefault(b, {})[a] = n
        self._mates: dict[str, list[str]] = {
            k: _rank(partners, max(0, cluster_size))
            for k, partners in by_key.items()
        }

    @classmethod
    def from_trace(
        cls, trace, *, top_k: int = 8, prefer_request: bool = False,
        cluster_size: int = 3, cluster_min_count: int = 2,
    ) -> "TransitionPredictor":
        """``trace`` is a ``core.on_demand.AccessTrace`` (or anything with
        the same table attributes; absent ones default empty). With
        ``prefer_request`` the coincidence-free ``request_transitions`` /
        ``request_pairs`` take precedence over the batch-level tables when
        non-empty (scheduler-attributed traffic, DESIGN.md §12.3)."""
        table = trace.transitions
        pairs = getattr(trace, "pairs", None)
        if prefer_request:
            table = getattr(trace, "request_transitions", None) or table
            pairs = getattr(trace, "request_pairs", None) or pairs
        return cls(
            table,
            top_k=top_k,
            phase_transitions=getattr(trace, "phase_transitions", None),
            transitions2=getattr(trace, "transitions2", None),
            pairs=pairs,
            cluster_size=cluster_size,
            cluster_min_count=cluster_min_count,
        )

    def __len__(self) -> int:
        return len(self._table)

    # -- serialization (server snapshot/restore, DESIGN.md §15.3) -----------
    def to_dict(self) -> dict:
        """The *ranked* tables as a plain-JSON dict. Counts are already
        folded into rank order by __init__, so the round-trip preserves
        exactly what ``follow`` consults — deterministically (every key
        sorted)."""
        return {
            "top_k": self.top_k,
            "table": {k: list(v) for k, v in sorted(self._table.items())},
            "phase_tables": {
                ph: {k: list(v) for k, v in sorted(tbl.items())}
                for ph, tbl in sorted(self._phase_tables.items())
            },
            # tuple context keys flatten to [a2, a1, [succ...]] rows
            "table2": [
                [a2, a1, list(v)] for (a2, a1), v in sorted(self._table2.items())
            ],
            "mates": {k: list(v) for k, v in sorted(self._mates.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TransitionPredictor":
        p = cls({}, top_k=d.get("top_k", 8))
        p._table = {k: list(v) for k, v in d.get("table", {}).items()}
        p._phase_tables = {
            ph: {k: list(v) for k, v in tbl.items()}
            for ph, tbl in d.get("phase_tables", {}).items()
        }
        p._table2 = {(a2, a1): list(v) for a2, a1, v in d.get("table2", [])}
        p._mates = {k: list(v) for k, v in d.get("mates", {}).items()}
        return p

    def successors(self, key: str, *, phase: str = "") -> list[str]:
        """First-order successors; with ``phase`` the phase-conditioned
        table is consulted first, falling back to the global one."""
        if phase:
            hit = self._phase_tables.get(phase, {}).get(key)
            if hit:
                return list(hit)
        return list(self._table.get(key, ()))

    def mates(self, key: str) -> list[str]:
        """The unit's co-access cluster (strongest partners first)."""
        return list(self._mates.get(key, ()))

    def follow(
        self, keys: Iterable[str], *, phase: str = "", prev: Iterable[str] = (),
    ) -> list[str]:
        """Ranked, deduped successor predictions for a set of observed
        units; the observed units themselves are never predicted. ``prev``
        is the previous observation batch — when given, second-order
        ``(prev_unit, cur_unit)`` context outranks first-order successors.
        Merge order follows the caller's key order (deduped), not a hash-
        randomized set, so identical runs prefetch in identical order."""
        ordered = list(dict.fromkeys(keys))
        seen = set(ordered)
        streams: list = []
        if prev and self._table2:
            prev_ordered = list(dict.fromkeys(prev))
            streams.extend(
                self._table2[(a2, a1)]
                for a2 in prev_ordered
                for a1 in ordered
                if (a2, a1) in self._table2
            )
        streams.extend(self.successors(k, phase=phase) for k in ordered)
        merged = [k for k in merge_hints(*streams) if k not in seen]
        if not self._mates:
            return merged
        # cluster expansion: a predicted unit drags its co-access mates in
        # behind it (they historically load together), never ahead of a
        # directly-predicted unit
        out = list(merged)
        have = seen | set(out)
        for k in merged:
            for m in self._mates.get(k, ()):
                if m not in have:
                    out.append(m)
                    have.add(m)
        return out


@dataclass
class PrefetchStats:
    hints: int = 0             # keys offered via hint()
    enqueued: int = 0          # keys accepted (cold + not already queued)
    loaded_units: int = 0
    loaded_bytes: int = 0
    skipped_resident: int = 0  # hints dropped because already resident/queued
    skipped_headroom: int = 0  # hints dropped by the host arbiter's gate
    batches: int = 0
    errors: int = 0
    observed: int = 0          # demand-accessed keys fed to observe()
    predicted: int = 0         # predictor-expanded hints accepted for loading
    preads_issued: int = 0     # pread syscalls the reader thread issued
    frames_fetched: int = 0    # store frames those reads delivered
    coalesced_bytes: int = 0   # payload bytes arriving via multi-frame preads

    def to_dict(self) -> dict:
        return {
            "hints": self.hints,
            "enqueued": self.enqueued,
            "loaded_units": self.loaded_units,
            "loaded_bytes": self.loaded_bytes,
            "skipped_resident": self.skipped_resident,
            "skipped_headroom": self.skipped_headroom,
            "batches": self.batches,
            "errors": self.errors,
            "observed": self.observed,
            "predicted": self.predicted,
            "preads_issued": self.preads_issued,
            "frames_fetched": self.frames_fetched,
            "coalesced_bytes": self.coalesced_bytes,
        }


@dataclass
class _Stage:
    """One host staging buffer: decoded units awaiting device upload."""

    items: list = field(default_factory=list)  # (key, np.ndarray, fetch_s)


class Prefetcher:
    """Background tier-1 loader driven by engine hints (DESIGN.md §8.2)."""

    def __init__(
        self,
        tiered: TieredParams,
        *,
        batch_units: int = 8,
        queue_depth: int = 2,
        name: str = "prefetch",
        predictor: Optional[TransitionPredictor] = None,
        read_gap_bytes: int = COALESCE_GAP,
    ):
        if tiered.store is None:
            raise ValueError("prefetcher needs a TieredParams with an optional store")
        self.tiered = tiered
        self.batch_units = max(1, batch_units)
        self.read_gap_bytes = read_gap_bytes  # pread coalescing gap (0 = off)
        self.predictor = predictor
        self._obs_prev: list[str] = []  # last observe() batch (2nd-order ctx)
        self.stats = PrefetchStats()
        # hint set keeps insertion order (FIFO priority) while deduping
        self._hints: OrderedDict[str, None] = OrderedDict()
        self._hint_lock = threading.Lock()
        self._wake = threading.Event()
        self._stage_q: queue.Queue[_Stage] = queue.Queue(maxsize=max(1, queue_depth))
        self._inflight = 0  # claimed by reader, not yet installed/aborted
        self._idle = threading.Condition(self._hint_lock)
        self._stop = threading.Event()
        self._reader = threading.Thread(target=self._read_loop, name=f"{name}-read", daemon=True)
        self._uploader = threading.Thread(target=self._upload_loop, name=f"{name}-upload", daemon=True)
        self._reader.start()
        self._uploader.start()

    # -- producer side ---------------------------------------------------------
    def hint(self, keys: Iterable[str]) -> int:
        """Offer access hints. Non-blocking; cold keys join the FIFO hint
        set, already-resident keys get an LRU-recency touch (a predicted
        reuse should not be the next eviction victim). Under a
        ``HostArbiter`` (DESIGN.md §13.1) cold hints are additionally
        gated on headroom: a speculative load that would force co-tenant
        evictions is dropped rather than queued — demand ``ensure()``
        stays ungated. Returns keys accepted for loading."""
        if self._stop.is_set():
            return 0
        accepted = 0
        touch: list[str] = []
        res = self.tiered.residency
        arb = self.tiered.arbiter
        with self._hint_lock:
            for k in keys:
                self.stats.hints += 1
                if k in self._hints or res.state_of(k) != "cold":
                    self.stats.skipped_resident += 1
                    if res.is_resident(k):
                        touch.append(k)
                    continue
                if arb is not None and not arb.prefetch_headroom(
                    self.tiered, self.tiered.unit_charge(k)
                ):
                    self.stats.skipped_headroom += 1
                    continue
                self._hints[k] = None
                accepted += 1
            self.stats.enqueued += accepted
        if touch:
            self.tiered.touch(touch)
        if accepted:
            self._wake.set()
        return accepted

    def observe(self, keys: Iterable[str]) -> int:
        """Feed the units a request step actually demand-accessed. With a
        ``TransitionPredictor`` attached, their learned successors join the
        hint set immediately — *ahead of* the engine/scheduler's own
        next-step hints, which only name units the current logits/routing
        already point at (DESIGN.md §11.3). Without a predictor this is a
        no-op. Returns the predicted keys accepted for loading."""
        if self.predictor is None or self._stop.is_set():
            return 0
        keys = list(keys)
        if not keys:
            return 0
        self.stats.observed += len(keys)
        prev, self._obs_prev = self._obs_prev, keys
        predicted = self.predictor.follow(
            keys, phase=self.tiered._phase, prev=prev)
        if not predicted:
            return 0
        accepted = self.hint(predicted)
        self.stats.predicted += accepted
        return accepted

    @property
    def hit_rate(self) -> float:
        return self.tiered.stats.prefetch_hit_rate

    # -- lifecycle -------------------------------------------------------------
    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every accepted hint is installed (or aborted)."""
        deadline = time.monotonic() + timeout
        with self._idle:
            while self._hints or self._inflight:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._idle.wait(min(remaining, 0.1))
        return True

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        self._wake.set()
        self._reader.join(timeout)
        self._uploader.join(timeout)
        # abort anything still staged so demand waiters never hang
        while True:
            try:
                stage = self._stage_q.get_nowait()
            except queue.Empty:
                break
            for key, _, _ in stage.items:
                self.tiered.abort_prefetch(key)
                self._done(1)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()

    # -- reader thread: fetch + decompress into host staging -------------------
    def _next_batch(self) -> list[str]:
        with self._hint_lock:
            batch = []
            while self._hints and len(batch) < self.batch_units:
                batch.append(self._hints.popitem(last=False)[0])
            if not self._hints:
                self._wake.clear()
            self._inflight += len(batch)
        return batch

    def _done(self, n: int) -> None:
        with self._idle:
            self._inflight -= n
            self._idle.notify_all()

    def _read_loop(self) -> None:
        store = self.tiered.store
        while not self._stop.is_set():
            if not self._wake.wait(timeout=0.05):
                continue
            batch = self._next_batch()
            if not batch:
                continue
            claimed = [k for k in batch if self.tiered.claim_for_prefetch(k)]
            self._done(len(batch) - len(claimed))
            if not claimed:
                continue
            stage = _Stage()
            ordered = sorted(claimed, key=lambda k: store.entries[k].offset)
            # one vectored pass for the whole batch: manifest-adjacent
            # frames coalesce into single preads (DESIGN.md §17.2). A
            # failing batch read falls back to per-key reads so one torn
            # frame aborts one key, not the whole batch.
            bufs: dict = {}
            rs = ReadStats()
            try:
                t_read0 = time.perf_counter()
                bufs = store.read_raw_many(
                    ordered, gap_threshold=self.read_gap_bytes, stats=rs)
                t_read = time.perf_counter() - t_read0
            except StoreError:
                bufs, t_read = {}, 0.0
            self.stats.preads_issued += rs.preads
            self.stats.frames_fetched += rs.frames
            self.stats.coalesced_bytes += rs.coalesced_bytes
            total_csize = sum(store.entries[k].csize for k in ordered) or 1
            for key in ordered:
                if self._stop.is_set():
                    self.tiered.abort_prefetch(key)
                    self._done(1)
                    continue
                try:
                    t0 = time.perf_counter()
                    if key in bufs:
                        buf = bufs[key]
                        # amortize the batch read csize-proportionally so
                        # per-key fetch_s still sums to wall time spent
                        t_io = t_read * (store.entries[key].csize / total_csize)
                    else:
                        t_io = 0.0
                        rs2 = ReadStats()
                        buf = store.read_raw(key, stats=rs2)
                        self.stats.preads_issued += rs2.preads
                        self.stats.frames_fetched += rs2.frames
                    arr = store.decode(key, buf)
                    stage.items.append(
                        (key, arr, t_io + time.perf_counter() - t0))
                except Exception:
                    self.stats.errors += 1
                    self.tiered.abort_prefetch(key)
                    self._done(1)
            if not stage.items:
                continue
            self.stats.batches += 1
            while not self._stop.is_set():
                try:
                    self._stage_q.put(stage, timeout=0.1)
                    break
                except queue.Full:
                    continue
            else:  # stopping with a full queue: roll the claims back
                for key, _, _ in stage.items:
                    self.tiered.abort_prefetch(key)
                    self._done(1)
        # shutdown: abort any hints claimed would-be (none claimed here);
        # outstanding hint-set entries are simply forgotten.

    # -- uploader thread: staged host arrays → device ---------------------------
    def _upload_loop(self) -> None:
        while not (self._stop.is_set() and self._stage_q.empty()):
            try:
                stage = self._stage_q.get(timeout=0.1)
            except queue.Empty:
                continue
            for key, arr, fetch_s in stage.items:
                try:
                    moved = self.tiered.install_prefetched(key, arr, fetch_s)
                    if moved:
                        self.stats.loaded_units += 1
                        self.stats.loaded_bytes += moved
                except Exception:
                    self.stats.errors += 1
                    self.tiered.abort_prefetch(key)
                finally:
                    self._done(1)
