"""⑥ Profile-guided re-tiering — closing the static-analysis loop
(DESIGN.md §11).

FaaSLight's central caveat is that static reachability can misclassify
indispensable code: a unit the analyzer deferred to tier-1 but every
request touches pays its fault latency on the first request after every
cold start, forever. The fix the field converged on (arXiv:2504.19283) is
*profiling*: serve real traffic once with telemetry on, then re-tier from
the observed access trace. ``replan_from_trace`` consumes an
``AccessTrace`` (core/on_demand.py) and rewrites the tier plan:

  * **promote** — tier-1 units the trace shows were demand-faulted join
    the cold-start hot set (``TierDecision.resident_units``); a whole-leaf
    tier-1 decision whose single unit faulted is promoted to tier-0
    outright (its bytes move from the optional store into the eager
    bundle). An optional ``max_promote_bytes`` budget caps the added
    cold-start bytes, hottest-first.
  * **demote** — preloaded resident units the profiled traffic never
    touched are dropped from the hot set (their bytes stop riding every
    cold start); a tier-0 *leaf* is demotable only when it is unreachable
    from every served entry.

**The safety invariant** (``check_tier0_superset``): the replanned tier-0
set must remain a superset of the entry-reachable leaves the original
plan held in tier-0. Dense reachable leaves have *no runtime fault
detector* — unlike vocab rows (exact pre-fault) and routed experts
(usage-mask retry), a demoted dense leaf would silently compute on
placeholder zeros. The demotion rule therefore never consults the trace
for tier-0 leaves (an adversarial trace cannot demote a reachable leaf),
and the invariant is re-verified on the final plan before it is returned
(tests/test_retier.py exercises both directions).

``retier_artifact`` materializes a replanned artifact next to the old one
by moving bytes between the tier-0 bundle and the optional store, and
publishes it with the checkpoint layer's rename-commit
(``checkpoint.manager.commit_dir``) so a crash mid-rewrite never leaves a
torn half-artifact where a server might cold-start from it.

Compaction is IO-bound, not CPU-bound (DESIGN.md §17): a tier-1 unit that
stays tier-1 has its compressed frame copied VERBATIM between stores
(``OptionalStoreWriter.add_raw`` — zero decode, zero recompress; decode
happens only for actual tier moves), and the rewritten blob is laid out
in the trace's observed co-access order (``coaccess_order`` over the
§11.1 request_pairs) so one sequential read warms a whole cluster.
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.checkpoint import tensorstore_lite as tsl
from repro.checkpoint.manager import commit_dir
from repro.core.on_demand import AccessTrace
from repro.core.optional_store import OptionalStore, OptionalStoreWriter
from repro.core.param_graph import ReachabilityReport
from repro.core.partition import TierDecision, TierPlan, Unit


@dataclass
class RetierReport:
    """What one profile→re-tier cycle changed, for logs and artifact.json."""

    promoted_resident: list = field(default_factory=list)  # units joining the hot set
    demoted_resident: list = field(default_factory=list)   # hot-set units dropped
    promoted_leaves: list = field(default_factory=list)    # whole leaves tier-1 → tier-0
    demoted_leaves: list = field(default_factory=list)     # whole leaves tier-0 → tier-1
    promoted_bytes: int = 0   # cold-start bytes added (promotions)
    demoted_bytes: int = 0    # cold-start bytes shed (demotions)
    budget_skipped: int = 0   # promotion candidates dropped by max_promote_bytes

    def summary(self) -> dict:
        return {
            "promoted_resident": len(self.promoted_resident),
            "demoted_resident": len(self.demoted_resident),
            "promoted_leaves": len(self.promoted_leaves),
            "demoted_leaves": len(self.demoted_leaves),
            "promoted_bytes": self.promoted_bytes,
            "demoted_bytes": self.demoted_bytes,
            "budget_skipped": self.budget_skipped,
        }


def required_tier0(plan: TierPlan, reach: ReachabilityReport) -> set:
    """The leaf paths re-tiering must never demote: entry-reachable leaves
    the original plan already proved indispensable (tier-0). This set is a
    function of the *plan and the static analysis only* — no trace input —
    which is what makes the §11.2 invariant adversarial-trace-proof."""
    return {
        p
        for p, d in plan.decisions.items()
        if d.tier == 0 and reach.reaching(p)
    }


def check_tier0_superset(plan: TierPlan, required: set) -> None:
    """Raise unless every required leaf is tier-0 in ``plan``."""
    missing = sorted(p for p in required if plan.decisions[p].tier != 0)
    if missing:
        raise ValueError(
            f"re-tier invariant violated: entry-reachable leaves left tier-0: "
            f"{missing[:5]}{'...' if len(missing) > 5 else ''}"
        )


def replan_from_trace(
    plan: TierPlan,
    trace: AccessTrace,
    reach: ReachabilityReport,
    *,
    promote_min_faults: int = 1,
    max_promote_bytes: Optional[int] = None,
    promote_leaves: bool = True,
    demote_untouched_residents: bool = True,
) -> tuple[TierPlan, RetierReport]:
    """Rewrite the tier plan from one profiling run's access trace.

    Deterministic: candidates are ranked by (fault count desc, key), so
    the same trace always yields the same plan (tests/test_retier.py).
    An empty trace (``batches == 0``) is a no-op for demotion — a
    misconfigured profiling run must not wipe the offline-stats hot set.
    """
    required = required_tier0(plan, reach)
    report = RetierReport()

    # -- rank promotion candidates globally (hottest first) -------------------
    candidates: list[tuple[int, Unit, str]] = []  # (faults, unit, path)
    for path, dec in plan.decisions.items():
        if dec.tier != 1:
            continue
        resident = set(dec.resident_units)
        for u in dec.units:
            n = trace.faults.get(u.key, 0)
            if u.key not in resident and n >= max(1, promote_min_faults):
                candidates.append((n, u, path))
    candidates.sort(key=lambda c: (-c[0], c[1].key))

    promote: dict[str, set] = {}  # path -> unit keys to add to the hot set
    spent = 0
    for n, u, path in candidates:
        if max_promote_bytes is not None and spent + u.nbytes > max_promote_bytes:
            report.budget_skipped += 1
            continue
        spent += u.nbytes
        promote.setdefault(path, set()).add(u.key)

    decisions: dict[str, TierDecision] = {}
    for path, dec in plan.decisions.items():
        if dec.tier == 0:
            # tier-0 demotion is *static-only*: an adversarial trace must
            # not be able to pull an entry-reachable dense leaf out from
            # under the compiled entries (no runtime fault detector exists
            # for dense access — see the module docstring).
            if path not in required and reach.reaching(path) == set():
                decisions[path] = TierDecision(
                    path, 1, "leaf",
                    "re-tier: unreachable from served entries", dec.nbytes,
                    units=(Unit(path, path, nbytes=dec.nbytes),),
                )
                report.demoted_leaves.append(path)
                report.demoted_bytes += dec.nbytes
            else:
                decisions[path] = dec
            continue

        added = promote.get(path, set())
        # whole-leaf promotion: the leaf's one unit was demand-faulted, so
        # it belongs in the eager bundle, not behind a first-request fault
        if (
            promote_leaves
            and dec.granularity == "leaf"
            and len(dec.units) == 1
            and dec.units[0].key in added
        ):
            n = trace.faults.get(dec.units[0].key, 0)
            decisions[path] = TierDecision(
                path, 0, "leaf", f"re-tier: faulted {n}x in profile", dec.nbytes,
            )
            report.promoted_leaves.append(path)
            report.promoted_bytes += dec.nbytes
            continue

        resident = list(dec.resident_units)
        if demote_untouched_residents and trace.batches > 0:
            kept, dropped = [], []
            for k in resident:
                (kept if trace.touches.get(k, 0) > 0 else dropped).append(k)
            resident = kept
            report.demoted_resident.extend(dropped)
            by_key = {u.key: u for u in dec.units}
            report.demoted_bytes += sum(by_key[k].nbytes for k in dropped if k in by_key)
        if added:
            ordered = sorted(added, key=lambda k: (-trace.faults.get(k, 0), k))
            resident = resident + [k for k in ordered if k not in resident]
            report.promoted_resident.extend(ordered)
            by_key = {u.key: u for u in dec.units}
            report.promoted_bytes += sum(by_key[k].nbytes for k in ordered if k in by_key)
        decisions[path] = dataclasses.replace(dec, resident_units=tuple(resident))

    new_plan = TierPlan(
        decisions=decisions, profile=plan.profile, entry_names=list(plan.entry_names)
    )
    check_tier0_superset(new_plan, required)  # the §11.2 invariant, re-proved
    return new_plan, report


def residency_overlay(plan: TierPlan) -> dict[str, list[str]]:
    """The portable residency state of a plan: tier-1 path → hot-set unit
    keys, hottest-first order preserved. This is what a fleet controller
    federates (DESIGN.md §14.1): unlike a ``TierPlan`` it carries no
    ``Unit`` objects, serializes to plain JSON, and can be applied to any
    replica's own plan via ``apply_overlay`` — including a replica in a
    different process restoring from a snapshot."""
    return {
        path: list(dec.resident_units)
        for path, dec in sorted(plan.decisions.items())
        if dec.tier == 1
    }


def apply_overlay(plan: TierPlan, overlay: dict[str, list[str]]) -> TierPlan:
    """Materialize a replica-local plan from a fleet residency overlay:
    each tier-1 decision's hot set is replaced by the overlay's entry,
    filtered to unit keys the decision actually owns (replicas with a
    slightly different unit split simply ignore foreign keys). Paths
    absent from the overlay — and every tier-0 decision — are untouched,
    so applying an overlay can never flip a tier (the §12.1 rule 2
    analogue for remote plans). Returns a NEW plan; the input is not
    mutated."""
    decisions = dict(plan.decisions)
    for path, keys in overlay.items():
        dec = decisions.get(path)
        if dec is None or dec.tier != 1:
            continue
        owned = {u.key for u in dec.units}
        decisions[path] = dataclasses.replace(
            dec, resident_units=tuple(k for k in keys if k in owned)
        )
    return TierPlan(
        decisions=decisions, profile=plan.profile, entry_names=list(plan.entry_names)
    )


def coaccess_order(keys: list, pairs: dict) -> list:
    """Order unit keys by observed co-access: greedy cluster chaining over
    the trace's §11.1 pair counts (``request_pairs`` preferred — per-request
    attribution is coincidence-free; batch ``pairs`` as fallback).

    Pairs are taken strongest-first; each pair merges the two keys'
    clusters (appending one chain onto the other) unless they already
    share one. Deterministic: ties break on the sorted (a, b) key pair,
    clusters are emitted by first appearance scanning ``sorted(keys)``,
    and keys with no co-access signal keep their sorted order at the end
    of their own singleton cluster. A key's cluster-internal order is the
    chain order the merges produced, so the strongest pairs end up
    byte-adjacent in the blob (tests/test_store_faults.py pins this)."""
    keys = list(keys)
    keyset = set(keys)
    cluster_of: dict = {k: [k] for k in keys}
    ranked = sorted(
        ((count, a, b) for (a, b), count in pairs.items()
         if a in keyset and b in keyset and count > 0),
        key=lambda t: (-t[0], t[1], t[2]),
    )
    for _, a, b in ranked:
        ca, cb = cluster_of[a], cluster_of[b]
        if ca is cb:
            continue
        ca.extend(cb)
        for k in cb:
            cluster_of[k] = ca
    out: list = []
    seen: set = set()
    for k in sorted(keys):
        c = cluster_of[k]
        if id(c) in seen:
            continue
        seen.add(id(c))
        out.extend(c)
    return out


def retier_artifact(
    artifact_dir: str,
    plan: TierPlan,
    *,
    out_dir: Optional[str] = None,
    report: Optional[RetierReport] = None,
    compress_level: int = 6,
    trace: Optional[AccessTrace] = None,
) -> dict:
    """Materialize a replanned two-tier artifact from an existing one.

    No model weights needed: bytes are moved between the old tier-0 bundle
    and the old optional store according to the new plan (a promoted leaf
    leaves the store for the bundle; a demoted leaf goes the other way;
    expert/row units stay put — only their hot-set membership changed,
    which lives in artifact.json). The new artifact is built in a
    ``.partial`` directory and published with the checkpoint layer's
    rename-commit (``checkpoint.manager.commit_dir``); ``out_dir`` must
    differ from ``artifact_dir`` because the rewrite streams from the old
    files while writing the new ones. Returns the new artifact.json meta.

    Units staying tier-1 are copied as raw compressed frames (byte-
    identical to the source store; zero recompressions for an unchanged
    plan — counter-asserted in tests). With a ``trace``, the blob is laid
    out in co-access order (``coaccess_order``); the manifest records the
    layout source and the meta a ``compaction`` block with the raw-copy /
    recompress split (DESIGN.md §17.1 and §17.2).
    """
    out_dir = out_dir if out_dir is not None else artifact_dir.rstrip("/") + "-retier"
    if os.path.abspath(out_dir) == os.path.abspath(artifact_dir):
        raise ValueError("retier_artifact reads artifact_dir while writing — "
                         "out_dir must be a different directory")
    # mmap: tier-0 is the bulk of the model and most of it is copied
    # through unchanged — stream it instead of materializing O(model)
    # host bytes (the source dir stays intact until the commit)
    old_tier0 = tsl.read_bundle(os.path.join(artifact_dir, "tier0"), mmap=True)
    store = OptionalStore(os.path.join(artifact_dir, "optional.blob"))
    try:
        tmp = out_dir.rstrip("/") + ".partial"
        if os.path.exists(tmp):
            import shutil

            shutil.rmtree(tmp)
        os.makedirs(tmp)

        tier0: dict[str, np.ndarray] = {}
        for path, dec in plan.decisions.items():
            if dec.tier != 0:
                continue
            if path in old_tier0:
                tier0[path] = old_tier0[path]
            elif path in store.entries:  # promoted whole leaf
                tier0[path] = store.fetch(path)
            else:
                raise KeyError(
                    f"tier-0 leaf {path!r} found in neither the old bundle "
                    f"nor the optional store — artifact/plan mismatch"
                )
        tsl.write_bundle(os.path.join(tmp, "tier0"), tier0)

        # tier-1 write order: co-access clusters from the trace when one is
        # provided (so one sequential read warms a cluster, §17.2), else the
        # source store's offset order (preserves an earlier compaction's
        # layout instead of resetting to plan order)
        unit_src: dict[str, str] = {}  # key -> owning leaf path
        for path, dec in plan.decisions.items():
            if dec.tier != 1:
                continue
            for unit in dec.units:
                unit_src[unit.key] = path
        t1_keys = sorted(
            unit_src,
            key=lambda k: store.entries[k].offset if k in store.entries else -1,
        )
        layout = {"source": "source-order"}
        if trace is not None:
            pairs = trace.request_pairs or trace.pairs
            if pairs:
                t1_keys = coaccess_order(t1_keys, pairs)
                layout = {"source": "coaccess",
                          "pairs": "request" if trace.request_pairs else "batch"}

        raw_copied = 0
        recompressed = 0
        with OptionalStoreWriter(
            os.path.join(tmp, "optional.blob"), level=compress_level,
            layout=layout,
        ) as w:
            for key in t1_keys:
                path = unit_src[key]
                if key in store.entries:
                    # stays tier-1: move the compressed frame verbatim —
                    # no decode, no recompress (the §17.1 copy rule)
                    w.add_raw(key, store.read_raw(key), store.entries[key])
                    raw_copied += 1
                elif path in old_tier0:  # demoted whole leaf
                    w.add(key, np.asarray(old_tier0[path]))
                    recompressed += 1
                else:
                    raise KeyError(
                        f"tier-1 unit {key!r} found in neither the "
                        f"optional store nor the old tier-0 bundle"
                    )

        new_store = OptionalStore(os.path.join(tmp, "optional.blob"))
        meta = {
            "profile": plan.profile.name,
            "entries": list(plan.entry_names),
            "tier0_bytes": plan.tier0_bytes,
            "tier1_raw_bytes": new_store.raw_bytes,
            "tier1_compressed_bytes": new_store.compressed_bytes,
            "retier": report.summary() if report is not None else {},
            "compaction": {
                "layout": layout,
                "raw_copied": raw_copied,
                "recompressed": recompressed,
            },
            "decisions": {
                p: {
                    "tier": d.tier,
                    "granularity": d.granularity,
                    "reason": d.reason,
                    "nbytes": d.nbytes,
                    "units": [u.key for u in d.units],
                    "resident_units": list(d.resident_units),
                }
                for p, d in plan.decisions.items()
            },
        }
        new_store.close()
        with open(os.path.join(tmp, "artifact.json"), "w") as f:
            json.dump(meta, f)

        commit_dir(tmp, out_dir)
        return meta
    finally:
        store.close()
