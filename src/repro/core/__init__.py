"""FaaSLight core: Program Analyzer (entry recognition, param-reachability
call graph, tier partitioning) + Code Generator (optional store, on-demand
loader, artifact builder) + the profile-guided re-tiering loop (access
telemetry, trace-driven replanner, predictive prefetch) and its online
form (the restart-free RetierDaemon) and its fleet form (the federated
FleetController). See DESIGN.md §4, §11, §12 and §14."""

from repro.core.analyzer import AnalysisResult, analyze, build_artifact, write_monolithic
from repro.core.arbiter import HostArbiter, HostArbiterStats
from repro.core.fleet import FleetController, FleetStats
from repro.core.entrypoints import (
    SERVING_MULTIMODAL_PROFILE,
    SERVING_PROFILE,
    TRAINING_PROFILE,
    DeploymentProfile,
    recognize_entries,
)
from repro.core.file_elim import eliminate_collections, eliminate_files
from repro.core.on_demand import (
    AccessTrace,
    LoadEvent,
    LoaderStats,
    ResidencyManager,
    TieredParams,
    placeholder_tree,
)
from repro.core.optional_store import (
    CorruptFrameError,
    OptionalStore,
    OptionalStoreWriter,
    ReadStats,
    StoreError,
    StoreSkewError,
    TornFrameError,
    write_store,
)
from repro.core.prefetch import Prefetcher, PrefetchStats, TransitionPredictor
from repro.core.param_graph import ReachabilityReport, build_reachability, entry_param_liveness
from repro.core.partition import TierDecision, TierPlan, Unit, build_tier_plan
from repro.core.retier import (
    RetierReport,
    apply_overlay,
    check_tier0_superset,
    coaccess_order,
    replan_from_trace,
    required_tier0,
    residency_overlay,
    retier_artifact,
)
from repro.core.retier_daemon import RetierDaemon, RetierDaemonStats
from repro.core.snapshot import (
    SNAPSHOT_VERSION,
    artifact_fingerprint,
    capture as capture_server_snapshot,
    restore as restore_server_snapshot,
)

__all__ = [
    "AnalysisResult",
    "analyze",
    "build_artifact",
    "write_monolithic",
    "DeploymentProfile",
    "SERVING_PROFILE",
    "SERVING_MULTIMODAL_PROFILE",
    "TRAINING_PROFILE",
    "recognize_entries",
    "eliminate_collections",
    "eliminate_files",
    "HostArbiter",
    "HostArbiterStats",
    "AccessTrace",
    "LoadEvent",
    "LoaderStats",
    "ResidencyManager",
    "TieredParams",
    "placeholder_tree",
    "Prefetcher",
    "PrefetchStats",
    "TransitionPredictor",
    "RetierReport",
    "RetierDaemon",
    "RetierDaemonStats",
    "FleetController",
    "FleetStats",
    "SNAPSHOT_VERSION",
    "artifact_fingerprint",
    "capture_server_snapshot",
    "restore_server_snapshot",
    "replan_from_trace",
    "required_tier0",
    "check_tier0_superset",
    "retier_artifact",
    "residency_overlay",
    "apply_overlay",
    "OptionalStore",
    "OptionalStoreWriter",
    "write_store",
    "StoreError",
    "TornFrameError",
    "CorruptFrameError",
    "StoreSkewError",
    "ReadStats",
    "coaccess_order",
    "ReachabilityReport",
    "build_reachability",
    "entry_param_liveness",
    "TierDecision",
    "TierPlan",
    "Unit",
    "build_tier_plan",
]
