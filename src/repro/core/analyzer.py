"""FaaSLight orchestration: application → tiered artifact.

``analyze()`` runs the Program Analyzer (entry recognition → reachability →
tier plan) purely abstractly — no weights needed, nothing allocated — and
``build_artifact()`` runs the Code Generator: given real weights it writes

    <outdir>/
      tier0.npz                  # indispensable weights, eager-loaded
      optional.blob              # tier-1 units, zlib kv store
      optional.blob.manifest.json
      artifact.json              # plan + profile + arch metadata

which is the optimized deployment package ("after2"). The monolithic
baseline ("before") and the collection-pruned variant ("after1") are also
writable for the paper's comparisons.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.entrypoints import DeploymentProfile, recognize_entries
from repro.core.file_elim import EliminationReport, eliminate_collections, eliminate_files
from repro.core.optional_store import OptionalStore, OptionalStoreWriter
from repro.core.param_graph import ReachabilityReport, build_reachability
from repro.core.partition import TierPlan, build_tier_plan
from repro.models.zoo import Model
from repro.utils.tree import flatten_with_paths


@dataclass
class AnalysisResult:
    plan: TierPlan
    reach: ReachabilityReport
    elim: EliminationReport
    profile: DeploymentProfile

    def summary(self) -> dict:
        s = self.plan.summary()
        s["dropped_collections_bytes"] = self.elim.dropped_bytes
        s["entries"] = self.reach.entry_names
        return s


def analyze(
    model: Model,
    profile: DeploymentProfile,
    *,
    collections: Optional[dict] = None,
    hot_units_stats: Optional[dict] = None,
    trace_B: int = 1,
    trace_S: int = 64,
) -> AnalysisResult:
    """The full Program Analyzer pass (abstract; no weights).

    ``collections`` is the full checkpoint tree ({"params": …, "opt_state":
    …}); only its *keys* matter here (file elimination is structural).
    Tracing shape: reachability is shape-independent for these models, so a
    small (B, S) keeps analysis instant even for the 123 B-param configs.
    """
    collections = collections if collections is not None else {"params": model.abstract()}
    _, elim = eliminate_collections(collections, for_training=profile.is_training)

    entries = recognize_entries(model, profile, B=trace_B, S=trace_S)
    abstract = model.abstract()
    reach = build_reachability(entries, abstract)
    plan = build_tier_plan(
        abstract, model.access(), reach, profile,
        axes=model.axes(), hot_units_stats=hot_units_stats,
    )
    return AnalysisResult(plan=plan, reach=reach, elim=elim, profile=profile)


# ---------------------------------------------------------------------------
# Code Generator: materialize the deployment package
# ---------------------------------------------------------------------------


def _slice_unit(arr: np.ndarray, unit) -> np.ndarray:
    for i in unit.sel:
        arr = arr[i]
    if unit.rows is not None:
        lo, hi = unit.rows
        arr = arr[lo:hi]
    return arr


def build_artifact(
    params: Any,
    result: AnalysisResult,
    outdir: str,
    *,
    compress_level: int = 6,
) -> dict:
    """Write the optimized two-tier package. Returns manifest summary."""
    os.makedirs(outdir, exist_ok=True)
    eliminate_files(outdir)
    plan = result.plan
    flat = dict(flatten_with_paths(params))

    # tier-0: one raw-binary bundle (eager-loaded at cold start)
    from repro.checkpoint import tensorstore_lite as tsl

    tier0 = {}
    for path, dec in plan.decisions.items():
        if dec.tier == 0:
            tier0[path] = np.asarray(flat[path])
    tsl.write_bundle(os.path.join(outdir, "tier0"), tier0)

    # tier-1: the lightweight file
    blob_path = os.path.join(outdir, "optional.blob")
    with OptionalStoreWriter(blob_path, level=compress_level) as w:
        for path, dec in plan.decisions.items():
            if dec.tier != 1:
                continue
            arr = np.asarray(flat[path])
            for unit in dec.units:
                w.add(unit.key, _slice_unit(arr, unit))

    store = OptionalStore(blob_path)
    meta = {
        "profile": result.profile.name,
        "entries": result.reach.entry_names,
        "tier0_bytes": plan.tier0_bytes,
        "tier1_raw_bytes": store.raw_bytes,
        "tier1_compressed_bytes": store.compressed_bytes,
        "decisions": {
            p: {
                "tier": d.tier,
                "granularity": d.granularity,
                "reason": d.reason,
                "nbytes": d.nbytes,
                "units": [u.key for u in d.units],
                "resident_units": list(d.resident_units),
            }
            for p, d in plan.decisions.items()
        },
    }
    store.close()
    meta_path = os.path.join(outdir, "artifact.json")
    tmpm = meta_path + ".partial"
    with open(tmpm, "w") as f:
        json.dump(meta, f)
    os.replace(tmpm, meta_path)
    return meta


def write_monolithic(collections: Any, outdir: str, *, pruned: bool = False) -> str:
    """The paper's *before* (full checkpoint) / *after1* (collection-pruned)
    baselines as single uncompressed raw bundles."""
    from repro.checkpoint import tensorstore_lite as tsl

    os.makedirs(outdir, exist_ok=True)
    if pruned:
        collections, _ = eliminate_collections(collections)
    flat = {}
    for coll, tree in collections.items():
        for path, leaf in flatten_with_paths(tree):
            flat[f"{coll}.{path}"] = np.asarray(leaf)
    prefix = os.path.join(outdir, "after1" if pruned else "before")
    tsl.write_bundle(prefix, flat)
    return prefix + ".bin"
