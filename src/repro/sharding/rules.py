"""Logical-axis sharding rules → PartitionSpec resolution.

Every param leaf carries logical axis names (from its ParamSpec); activations
are constrained at block boundaries with logical names. Rules map logical
names to *ordered candidate lists* of mesh axes; resolution is greedy with
divisibility checks and first-wins conflict handling, so the same rule set
works across all ten architectures (e.g. kv_heads=8 on a 16-way model axis
simply falls back to replication instead of failing).

Parallelism coverage (DESIGN.md §6):
  DP   — "batch" → ("pod", "data")
  FSDP — params' "embed" → "data" (toggle: ModelConfig.fsdp)
  TP   — "heads"/"ffn"/"vocab" → "model"
  EP   — "experts" → "model" (divisibility-gated, else TP-within-expert)
  SP   — "kv_seq"/"seq_shard" → "model" for long-context decode
  PP   — separate stage-axis pipeline in repro.training.pipeline
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.utils.tree import flatten_axes_tree, flatten_with_paths, tree_from_flat

# logical axis -> ordered mesh-axis candidates (first divisible unused wins)
PARAM_RULES: dict[str, tuple[str, ...]] = {
    "embed": ("data",),  # FSDP: shard the d_model dim of weights over data
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
}

ACT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    # boundary-only context parallelism: the scan-carried layer-boundary
    # activation (= the remat-saved residual) shards its seq dim over
    # "model"; inside the block the first consumer re-gathers it. Sharding
    # seq *inside* blocks would double-book the model axis against TP
    # (ffn/heads) and makes XLA all-gather entire weight matrices instead
    # (observed 13 TB/device/step; see EXPERIMENTS.md §Perf).
    "seq_shard": ("model",),
    "embed": (),
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "kv_seq": ("model",),  # SP: shard long KV caches over model
    # MoE dispatch-buffer capacity dim: token-parallel over the batch axes.
    # Without this XLA contracts expert matmuls over the FSDP-sharded embed
    # dim and all-reduces (E, C, f) partial sums — 289 GB/device/step on
    # deepseek train_4k (EXPERIMENTS.md §Perf cell 1).
    "moe_cap": (),  # variant B: capacity replicated (EP-only dispatch)
}


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.param_rules = dict(PARAM_RULES)
        self.act_rules = dict(ACT_RULES)


_STATE = _State()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], param_rules: Optional[dict] = None, act_rules: Optional[dict] = None):
    """Ambient mesh + rules for constrain()/param_shardings()."""
    old = (_STATE.mesh, _STATE.param_rules, _STATE.act_rules)
    _STATE.mesh = mesh
    if param_rules is not None:
        _STATE.param_rules = dict(param_rules)
    if act_rules is not None:
        _STATE.act_rules = dict(act_rules)
    try:
        yield
    finally:
        _STATE.mesh, _STATE.param_rules, _STATE.act_rules = old


def set_rules(param_rules: Optional[dict] = None, act_rules: Optional[dict] = None) -> None:
    if param_rules is not None:
        _STATE.param_rules = dict(param_rules)
    if act_rules is not None:
        _STATE.act_rules = dict(act_rules)


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def resolve_pspec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict[str, tuple[str, ...]],
) -> PartitionSpec:
    """Greedy, divisibility-aware logical->physical resolution.

    A logical axis may map to a *group* of mesh axes (e.g. batch ->
    ("pod", "data")): the group is taken as one PartitionSpec entry when the
    dim is divisible by the combined size, otherwise we retry with suffixes
    of the group, otherwise replicate.
    """
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set[str] = set()
    entries: list = []
    for dim, name in zip(shape, axes):
        assigned = None
        if name is not None:
            cands = rules.get(name, ())
            # composite assignment: try the full candidate tuple, then suffixes
            group = [a for a in cands if a in mesh_sizes and a not in used]
            while group:
                size = int(np.prod([mesh_sizes[a] for a in group]))
                if dim % size == 0:
                    assigned = tuple(group)
                    used.update(group)
                    break
                group = group[1:]
        if assigned is None:
            entries.append(None)
        elif len(assigned) == 1:
            entries.append(assigned[0])
        else:
            entries.append(assigned)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def param_shardings(logical_tree, abstract_tree, mesh: Optional[Mesh] = None, fsdp: bool = True):
    """Tree of NamedShardings matching an abstract param tree."""
    mesh = mesh or _STATE.mesh
    rules = dict(_STATE.param_rules)
    if not fsdp:
        rules["embed"] = ()
    flat_axes = dict(flatten_axes_tree(logical_tree))
    out = {}
    for path, leaf in flatten_with_paths(abstract_tree):
        axes = flat_axes[path]
        spec = resolve_pspec(axes, leaf.shape, mesh, rules)
        out[path] = NamedSharding(mesh, spec)
    return tree_from_flat(out)


def spec_shard_divisor(spec: PartitionSpec, mesh: Mesh) -> int:
    """Number of distinct shards a PartitionSpec splits an array into —
    the product of the sizes of every mesh axis the spec names. Per-device
    bytes of a sharded array are ``nbytes / divisor`` (a fully replicated
    spec returns 1: every device holds all the bytes). This is the factor
    the tiered residency layer charges its device budget with (DESIGN.md
    §15.1)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    div = 1
    for entry in spec:
        if entry is None:
            continue
        for ax in entry if isinstance(entry, tuple) else (entry,):
            div *= sizes.get(ax, 1)
    return div


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint under the ambient mesh; no-op without one."""
    mesh = _STATE.mesh
    if mesh is None:
        return x
    spec = resolve_pspec(axes, x.shape, mesh, _STATE.act_rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
