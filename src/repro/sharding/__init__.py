from repro.sharding.rules import (
    ACT_RULES,
    PARAM_RULES,
    constrain,
    current_mesh,
    param_shardings,
    resolve_pspec,
    set_rules,
    spec_shard_divisor,
    use_mesh,
)

__all__ = [
    "ACT_RULES",
    "PARAM_RULES",
    "constrain",
    "current_mesh",
    "param_shardings",
    "resolve_pspec",
    "set_rules",
    "spec_shard_divisor",
    "use_mesh",
]
