"""Continuous-batching scheduler (DESIGN.md §9).

Covers the scheduler contract:
  * interleaved-admission equivalence — greedy tokens of concurrently
    scheduled requests match the same requests run sequentially through
    ``generate()``;
  * slot reuse — more requests than slots all complete, FIFO, with the
    compiled batch shape never exceeded;
  * admission rejection — an over-length request fails with an error and
    the loop keeps serving (and ``generate()`` itself raises ValueError,
    not a stripped-under-``-O`` assert);
  * pin-vs-eviction under a tight device budget — one slot's pinned
    working set is never evicted while other slots fault (threaded);
  * budgeted end-to-end — scheduler outputs under an eviction-pressure
    budget still match the full baseline;
  * hint merging is round-robin-fair across slots;
  * paged-KV lifecycle (DESIGN.md §16.2) — pages freed at retire are
    reused, failed requests leak no pages, and pool exhaustion is a clean
    admission rejection.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import DeploymentProfile, analyze, build_artifact, write_monolithic
from repro.core.prefetch import merge_hints
from repro.models.zoo import build_model
from repro.serving import ContinuousBatchingScheduler, GenerationEngine, cold_start

from test_prefetch import COLS, ROWS, UNIT_BYTES, _leaf_rows, _mini

ARCH = "mixtral-8x22b"
PROMPT_LEN = 6
MAX_SEQ = 16


@pytest.fixture(scope="module")
def app(tmp_path_factory):
    cfg = get_reduced(ARCH).replace(collect_moe_usage=True)
    model = build_model(cfg)
    profile = DeploymentProfile(resident_experts=1, hot_vocab_fraction=0.25,
                                min_tier1_bytes=1024, vocab_row_group=128)
    res = analyze(model, profile, trace_B=1, trace_S=16)
    params = model.init(jax.random.PRNGKey(0))
    outdir = str(tmp_path_factory.mktemp("sched"))
    write_monolithic({"params": params, "opt_state": {}}, outdir)
    build_artifact(params, res, outdir)
    return cfg, model, res, outdir


def _prompts(cfg, n, seed0=0):
    return [
        np.asarray(jax.random.randint(jax.random.PRNGKey(seed0 + i), (PROMPT_LEN,), 0, cfg.vocab_size))
        for i in range(n)
    ]


def _sequential_reference(cfg, model, res, outdir, prompts, steps, **cold_kw):
    outs = []
    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),), **cold_kw) as server:
        eng = GenerationEngine(server, max_seq=MAX_SEQ)
        for p, n in zip(prompts, steps):
            out, _ = eng.generate(jnp.asarray(p[None, :]), n)
            outs.append(np.asarray(out[0]))
    return outs


def test_interleaved_admission_matches_sequential(app):
    """Five requests with staggered lengths through three slots: every
    request's greedy tokens equal its solo sequential run."""
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 5)
    steps = [5, 3, 6, 2, 4]  # staggered completions force interleaving
    refs = _sequential_reference(cfg, model, res, outdir, prompts, steps)

    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),)) as server:
        sched = ContinuousBatchingScheduler(
            GenerationEngine(server, max_seq=MAX_SEQ), max_batch=3)
        reqs = [sched.submit(p, n) for p, n in zip(prompts, steps)]
        sched.run()

    for r, ref, n in zip(reqs, refs, steps):
        assert r.done and r.error is None
        assert r.stats.steps == n  # prefill token + per-decode accounting
        np.testing.assert_array_equal(r.output, ref)
    assert sched.stats.completed == 5
    assert sched.stats.max_active <= 3


def test_slot_reuse_after_completion(app):
    """More requests than slots: freed slots re-admit from the queue and
    every request completes over the single compiled batch shape."""
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 6, seed0=20)
    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),)) as server:
        sched = ContinuousBatchingScheduler(
            GenerationEngine(server, max_seq=MAX_SEQ), max_batch=2)
        reqs = [sched.submit(p, 3) for p in prompts]
        sched.run()
    assert all(r.done and r.error is None for r in reqs)
    assert [len(r.out) for r in reqs] == [3] * 6
    assert sched.stats.admitted == 6 and sched.stats.completed == 6
    assert sched.stats.max_active <= 2
    # FIFO fairness: completion order respects arrival for equal lengths
    finish = [r.finished_t for r in reqs]
    assert finish == sorted(finish)


def test_over_length_rejected_loop_survives(app):
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 2, seed0=40)
    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),)) as server:
        eng = GenerationEngine(server, max_seq=MAX_SEQ)
        # the engine itself must raise, not assert (stripped under -O)
        with pytest.raises(ValueError, match="max_seq"):
            eng.generate(jnp.asarray(prompts[0][None, :]), MAX_SEQ)
        sched = ContinuousBatchingScheduler(eng, max_batch=2)
        ok1 = sched.submit(prompts[0], 3)
        bad = sched.submit(np.zeros(MAX_SEQ, np.int32), 4)  # over-length
        ok2 = sched.submit(prompts[1], 3)
        sched.run()
    assert bad.done and bad.error is not None and "rejected" in bad.error
    assert bad.out == []
    for r in (ok1, ok2):
        assert r.done and r.error is None and len(r.out) == 3
    assert sched.stats.rejected == 1 and sched.stats.completed == 2


def test_scheduler_under_budget_matches_full(app):
    """Eviction pressure (budget = tier-1/2) must not change any request's
    tokens: the union-fault path pins every active slot's working set for
    the step."""
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 4, seed0=60)
    steps = [4, 4, 4, 4]
    refs = _sequential_reference(cfg, model, res, outdir, prompts, steps)
    budget = res.plan.tier1_bytes // 2
    with cold_start(model, outdir, res, mode="after2", warm_shapes=((1, PROMPT_LEN),),
                    device_budget_bytes=budget, prefetch=True) as server:
        sched = ContinuousBatchingScheduler(
            GenerationEngine(server, max_seq=MAX_SEQ), max_batch=4)
        reqs = [sched.submit(p, n) for p, n in zip(prompts, steps)]
        sched.run()
        resident = server.tiered.resident_bytes
    assert resident <= budget
    for r, ref in zip(reqs, refs):
        assert r.done and r.error is None
        np.testing.assert_array_equal(r.output, ref)


def test_active_slot_pins_survive_other_slots_faults(tmp_path):
    """The step invariant behind union faulting: while one slot's units
    are pinned (mid-step), other slots hammering ensure() under a tight
    budget evict only each other — never the pinned working set."""
    budget = 4 * UNIT_BYTES
    tp, data, units = _mini(tmp_path, budget=budget)
    slot_a = [u.key for u in units[:2]]  # the active step's pinned set
    slot_b = [u.key for u in units[2:]]  # 6 cold units fighting for 2 lanes
    tp.ensure(slot_a, pin=True)
    errors: list = []

    def faulter(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(30):
                tp.ensure(list(rng.choice(slot_b, size=2, replace=False)))
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=faulter, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    for u in units[:2]:
        assert tp.is_resident(u.key)
        assert not tp.residency.was_evicted(u.key)
        np.testing.assert_array_equal(_leaf_rows(tp, u), data[u.rows[0]:u.rows[1]])
    # victims always existed among slot B's unpinned units → never over budget
    assert tp.residency.max_resident_bytes <= budget
    tp.release(slot_a)
    assert tp.residency.resident_bytes <= budget


def test_pages_freed_at_retire_are_reused(app):
    """Paged-KV lifecycle (DESIGN.md §16.2): every grant returns at
    retire, the pool's books balance, and freed pages serve the next
    admission wave (6 requests over 2 slots never need more than 2
    slots' worth of pages)."""
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 6, seed0=80)
    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),)) as server:
        sched = ContinuousBatchingScheduler(
            GenerationEngine(server, max_seq=MAX_SEQ), max_batch=2,
            kv_page_size=4)
        pool = sched.page_pool
        per_req = pool.pages_for(PROMPT_LEN + 3)
        reqs = [sched.submit(p, 3) for p in prompts]
        sched.run()
    assert all(r.done and r.error is None for r in reqs)
    pool.assert_consistent()
    assert pool.used_pages == 0  # every retire freed its grant
    assert pool.stats.allocs == 6 and pool.stats.frees == 6
    # reuse, not growth: peak concurrent pages is two slots' worth
    assert pool.stats.high_water_pages <= 2 * per_req
    assert sched.stats.kv_pages_high_water == pool.stats.high_water_pages
    # the decode accounting ran and the paged number is the smaller one
    assert 0 < sched.stats.kv_tokens_paged <= sched.stats.kv_tokens_dense


def test_failed_requests_leak_no_pages(app):
    """Both failure paths return the grant: a prefill that raises frees
    before the slot is reused, and a decode-step failure frees every
    active slot's pages."""
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 2, seed0=90)
    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),)) as server:
        eng = GenerationEngine(server, max_seq=MAX_SEQ)
        sched = ContinuousBatchingScheduler(eng, max_batch=2)
        pool = sched.page_pool

        # prefill failure: admission grants pages, then prefill raises
        real_prefill = eng.prefill_step
        def boom(*a, **kw):
            raise RuntimeError("injected prefill fault")
        eng.prefill_step = boom
        r1 = sched.submit(prompts[0], 3)
        sched.run()
        assert r1.done and "prefill failed" in r1.error
        pool.assert_consistent()
        assert pool.used_pages == 0
        eng.prefill_step = real_prefill

        # decode failure: requests admit fine, then the step raises
        real_decode = eng.decode_once
        def boom2(*a, **kw):
            raise RuntimeError("injected decode fault")
        eng.decode_once = boom2
        r2 = sched.submit(prompts[1], 3)
        sched.run()
        assert r2.done and "decode step failed" in r2.error
        pool.assert_consistent()
        assert pool.used_pages == 0
        eng.decode_once = real_decode

        # the loop survived both: a healthy request still completes
        r3 = sched.submit(prompts[0], 2)
        sched.run()
    assert r3.done and r3.error is None and len(r3.out) == 2
    assert pool.used_pages == 0


def test_page_exhaustion_rejects_cleanly(app):
    """A pool too small for a request rejects it at admission — slot
    state untouched, no partial grant — while smaller requests keep
    being served from the same pool."""
    cfg, model, res, outdir = app
    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),)) as server:
        sched = ContinuousBatchingScheduler(
            GenerationEngine(server, max_seq=MAX_SEQ), max_batch=2,
            kv_page_size=4, kv_pages=2)  # 8 positions total
        pool = sched.page_pool
        big = sched.submit(_prompts(cfg, 1, seed0=95)[0], 4)  # 6+4 → 3 pages
        small_prompt = np.asarray([1, 2], np.int32)
        small = sched.submit(small_prompt, 2)                 # 2+2 → 1 page
        sched.run()
    assert big.done and big.error is not None
    assert "kv page pool exhausted" in big.error and big.out == []
    assert small.done and small.error is None and len(small.out) == 2
    assert sched.stats.rejected == 1 and sched.stats.completed == 1
    pool.assert_consistent()
    assert pool.used_pages == 0 and pool.stats.exhausted == 1
    assert all(s is None for s in sched._slots)  # slot state clean


def test_merge_hints_round_robin_fair():
    merged = merge_hints(["a1", "a2", "a3"], ["b1", "b2"], ["a1", "c1"])
    assert merged == ["a1", "b1", "a2", "b2", "c1", "a3"]
    assert merge_hints() == []
    assert merge_hints([], ["x"], []) == ["x"]
