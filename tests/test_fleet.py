"""Fleet controller: federated trace aggregation + learned pre-warm
(DESIGN.md §14).

Covers the federation contract:
  * one ``sync()`` cycle federates one replica's demand faults to every
    other replica — pulled, merged, replanned ONCE, pushed as a residency
    overlay and preloaded with exact bytes;
  * retention: a unit a push warmed STOPS faulting, and must stay in the
    overlay on decayed touches alone (regression: replanning from the
    pristine base plan each cycle made residency require ongoing faults,
    so the fleet demoted its own pre-warm, refaulted it, re-admitted it —
    a fleet-wide eviction/refault oscillation);
  * failure isolation: a replica whose push raises is recorded and
    skipped, its loader untouched, and the cycle completes for the rest;
  * the §12.1 invariant is re-proved ON THE REPLICA: ``apply_plan`` of a
    plan that flips an entry-reachable tier-0 leaf raises strictly before
    any mutation;
  * warm bootstrap: ``snapshot()`` → ``restore()`` round-trips the fleet
    state byte-identically, and a late joiner registered against a
    restored controller is resident before it serves;
  * pull-order independence (hypothesis, `slow`): the overlay and history
    a sync produces do not depend on replica registration/poll order;
  * predictor determinism: equal transition counts rank tie-broken by
    key, never by table insertion order.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (
    AccessTrace,
    FleetController,
    OptionalStore,
    RetierDaemon,
    TieredParams,
    TransitionPredictor,
)
from repro.core.entrypoints import SERVING_PROFILE
from repro.core.optional_store import write_store
from repro.core.param_graph import ReachabilityReport
from repro.core.partition import TierDecision, TierPlan, Unit

import jax.numpy as jnp

ROWS, COLS, N_UNITS = 16, 32, 8
UNIT_BYTES = ROWS * COLS * 4


def _replica(tmp_path, name, *, budget=None, resident=(), with_head=False):
    """One row-tiered leaf over a real optional store + static reach —
    the same mini fixture the daemon tests use, one store per replica."""
    rng = np.random.default_rng(0)  # same bytes on every replica
    data = rng.standard_normal((N_UNITS * ROWS, COLS)).astype(np.float32)
    units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * ROWS, (g + 1) * ROWS), nbytes=UNIT_BYTES)
        for g in range(N_UNITS)
    )
    decisions = {
        "emb": TierDecision("emb", 1, "rows", "test", data.nbytes, units=units,
                            resident_units=tuple(resident)),
    }
    reachable = {"emb": {"prefill"}}
    tree = {"emb": jnp.zeros(data.shape, jnp.float32)}
    if with_head:
        decisions["head"] = TierDecision("head", 0, "leaf", "test", 64)
        reachable["head"] = {"decode_step"}
        tree["head"] = jnp.zeros((4, 4), jnp.float32)
    plan = TierPlan(decisions, SERVING_PROFILE, [])
    path = str(tmp_path / f"{name}.blob")
    write_store(path, [(u.key, data[u.rows[0]: u.rows[1]]) for u in units])
    tp = TieredParams(tree, plan, OptionalStore(path), device_budget_bytes=budget)
    reach = ReachabilityReport(entry_names=["prefill", "decode_step"],
                               reachable=reachable)
    daemon = RetierDaemon(tp, reach, interval_steps=10_000)
    return tp, data, units, daemon


def _rows_of(tp, units, g):
    lo, hi = units[g].rows
    return np.asarray(tp.leaf("emb"))[lo:hi]


# ---------------------------------------------------------------------------
# one federation cycle
# ---------------------------------------------------------------------------

def test_sync_federates_one_replicas_faults_to_all(tmp_path):
    tp0, data, units, d0 = _replica(tmp_path, "r0")
    tp1, _, _, d1 = _replica(tmp_path, "r1")
    keys = [u.key for u in units]
    fleet = FleetController()
    fleet.register("r0", d0)
    fleet.register("r1", d1)

    tp0.ensure([keys[4], keys[5]])  # replica 0 explores; replica 1 is idle
    summary = fleet.sync()

    assert summary["replanned"] and sorted(summary["pushed"]) == ["r0", "r1"]
    ov = fleet.overlay
    assert set(ov["emb"]) == {keys[4], keys[5]}
    # replica 1 never touched rg4/rg5, yet they are resident — exact bytes,
    # loaded by the push's preload (no prefetcher → synchronous path)
    for g in (4, 5):
        assert tp1.is_resident(keys[g])
        np.testing.assert_array_equal(
            _rows_of(tp1, units, g), data[g * ROWS:(g + 1) * ROWS])
    fs = fleet.stats
    assert fs.syncs == 1 and fs.replans == 1
    assert fs.pushes == 2 and fs.push_failures == 0
    assert fs.pulls == 2 and fs.empty_windows == 1  # r1 had nothing new
    assert d1.stats.remote_applies == 1 and d1.stats.pulls == 1


def test_pull_window_survives_local_tick_cadence(tmp_path):
    """A local tick rotating the live trace must not hide that window from
    the next fleet pull — ticks fold windows into the un-pulled
    accumulator, and the pull drains it."""
    tp, _, units, daemon = _replica(tmp_path, "r0")
    keys = [u.key for u in units]
    tp.ensure([keys[3]])
    daemon.tick()           # local tick consumes the live window...
    tp.ensure([keys[6]])
    w = daemon.pull_window()
    assert w is not None    # ...but the fleet still sees BOTH observations
    assert keys[3] in w.faults and keys[6] in w.faults
    assert daemon.pull_window() is None  # drained — nothing new since


def test_retention_no_promote_demote_oscillation(tmp_path):
    """Once a push warms a unit it stops faulting; decayed TOUCHES alone
    must keep it in the overlay (fault admits, touch retains), and only a
    unit the whole fleet stops touching decays out."""
    tp0, _, units, d0 = _replica(tmp_path, "r0")
    tp1, _, _, d1 = _replica(tmp_path, "r1")
    keys = [u.key for u in units]
    fleet = FleetController()
    fleet.register("r0", d0)
    fleet.register("r1", d1)

    tp0.ensure([keys[4], keys[5]])  # both admitted by fault
    fleet.sync()
    assert set(fleet.overlay["emb"]) == {keys[4], keys[5]}

    for cycle in range(3):  # warm hits: touches only, zero new faults
        tp0.ensure([keys[4]])
        fleet.sync()
        assert keys[4] in fleet.overlay["emb"], f"dropped on cycle {cycle}"
        assert tp1.is_resident(keys[4])
    # rg5 was never touched again: decayed out of the history (two halvings
    # hit the prune threshold) and demoted everywhere — retention is by
    # evidence, not tenure
    assert keys[5] not in fleet.overlay["emb"]
    assert not tp1.is_resident(keys[5])


# ---------------------------------------------------------------------------
# failure isolation + the on-replica invariant
# ---------------------------------------------------------------------------

def test_push_failure_is_isolated_to_the_failing_replica(tmp_path):
    tp0, data, units, d0 = _replica(tmp_path, "r0")
    tp1, _, _, d1 = _replica(tmp_path, "r1")
    tp2, _, _, d2 = _replica(tmp_path, "r2")
    keys = [u.key for u in units]
    fleet = FleetController()
    for name, d in (("r0", d0), ("r1", d1), ("r2", d2)):
        fleet.register(name, d)

    def boom(plan, **kw):
        raise RuntimeError("replica wedged")
    d1.apply_plan = boom

    tp0.ensure([keys[4]])
    summary = fleet.sync()

    assert fleet.stats.push_failures == 1 and fleet.stats.pushes == 2
    assert "replica wedged" in summary["failed"]["r1"]
    assert "replica wedged" in fleet.last_errors["r1"]
    # the healthy replicas were warmed; the wedged one's loader untouched
    assert tp2.is_resident(keys[4])
    assert not tp1.is_resident(keys[4])
    assert tp1.plan.decisions["emb"].resident_units == ()
    # the next cycle still serves everyone that works
    tp0.ensure([keys[6]])
    fleet.sync()
    assert tp2.is_resident(keys[6])


def test_apply_plan_reproves_invariant_before_any_mutation(tmp_path):
    """§12.1 rule 1, federated: the REPLICA re-proves tier-0 ⊇
    entry-reachable on a remote plan — a plan that flips a required leaf
    is rejected whole, before a byte moves."""
    tp, _, units, daemon = _replica(tmp_path, "r0", with_head=True)
    bad = TierPlan(
        {
            **tp.plan.decisions,
            "head": dataclasses.replace(
                tp.plan.decisions["head"], tier=1,
                units=(Unit("head", "head", nbytes=64),)),
        },
        SERVING_PROFILE, [],
    )
    before = tp.plan
    with pytest.raises(ValueError, match="invariant"):
        daemon.apply_plan(bad)
    assert tp.plan is before                      # nothing swapped
    assert daemon.stats.remote_applies == 0       # nothing counted applied
    assert daemon.stats.promoted_units == daemon.stats.demoted_units == 0
    assert tp.stats.evictions == 0


# ---------------------------------------------------------------------------
# snapshot / restore + warm bootstrap
# ---------------------------------------------------------------------------

def test_snapshot_restore_roundtrip_and_late_join_bootstrap(tmp_path):
    tp0, data, units, d0 = _replica(tmp_path, "r0")
    keys = [u.key for u in units]
    fleet = FleetController(decay=0.25, sync_preload=True)
    fleet.register("r0", d0)
    tp0.ensure([keys[2], keys[7]])
    fleet.sync()

    snap = fleet.snapshot()
    wire = json.dumps(snap, sort_keys=True)       # must be plain JSON
    fleet2 = FleetController.restore(json.loads(wire))
    # byte-identical round-trip: restore() loses nothing snapshot() kept
    assert json.dumps(fleet2.snapshot(), sort_keys=True) == wire
    assert fleet2.decay == 0.25 and fleet2.sync_preload is True

    # a replica the restored controller has NEVER met joins warm: the
    # overlay is applied + preloaded synchronously inside register()
    tp_new, _, _, d_new = _replica(tmp_path, "late")
    assert fleet2.register("late", d_new) is True
    assert fleet2.stats.bootstraps == 1
    for g in (2, 7):
        assert tp_new.is_resident(keys[g])
        np.testing.assert_array_equal(
            _rows_of(tp_new, units, g), data[g * ROWS:(g + 1) * ROWS])
    assert d_new.stats.remote_applies == 1


def test_restore_rejects_unknown_snapshot_version():
    with pytest.raises(ValueError, match="version"):
        FleetController.restore({"version": 99})


def test_register_duplicate_name_rejected(tmp_path):
    _, _, _, daemon = _replica(tmp_path, "r0")
    fleet = FleetController()
    fleet.register("r0", daemon)
    with pytest.raises(ValueError, match="already registered"):
        fleet.register("r0", daemon)
    fleet.unregister("r0")
    assert fleet.replicas == []
    fleet.register("r0", daemon)  # name reusable after unregister


# ---------------------------------------------------------------------------
# pull-order independence (§14.1 rule 1, property-tested)
# ---------------------------------------------------------------------------

class _StubDaemon:
    """The controller-facing daemon surface, with a canned window and a
    recording apply — lets the property run hundreds of fleets without
    stores or loaders."""

    def __init__(self, tp, reach, window):
        self.tiered = tp
        self.reach = reach
        self._window = window
        self.applied = []

    def pull_window(self):
        w, self._window = self._window, None
        return w

    def apply_plan(self, plan, *, trace=None, sync_preload=False):
        self.applied.append(plan)
        return {"promoted": 0, "demoted": 0}


@pytest.mark.slow
def test_sync_result_independent_of_poll_order(tmp_path):
    """Whatever windows the replicas hand over, registering (and hence
    polling) them in a different order yields the SAME overlay and the
    SAME federated history — byte-identically (§14.1 rule 1)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    keys = [f"emb#rg{g}" for g in range(N_UNITS)]
    tp, _, _, real = _replica(tmp_path, "base")

    @st.composite
    def windows_and_order(draw):
        n = draw(st.integers(min_value=2, max_value=4))
        windows = []
        for _ in range(n):
            w = AccessTrace()
            for _ in range(draw(st.integers(min_value=0, max_value=4))):
                ks = draw(st.lists(st.sampled_from(keys), min_size=1,
                                   max_size=4, unique=True))
                cold = [k for k in ks if draw(st.booleans())]
                w.record(ks, cold, draw(st.sampled_from(["prefill", "decode", ""])))
            windows.append(w)
        order = draw(st.permutations(list(range(n))))
        return windows, order

    def one_fleet(windows, idx_order):
        fleet = FleetController()
        for i in idx_order:
            # fresh stubs per fleet: pull_window drains the window, and
            # merging into an empty trace deep-copies the shared original
            fleet.register(f"r{i}", _StubDaemon(
                tp, real.reach, AccessTrace().merge(windows[i], decay=1.0)))
        fleet.sync()
        h = fleet.history
        return fleet.overlay, None if h is None else h.to_json()

    @settings(max_examples=60, deadline=None)
    @given(windows_and_order())
    def check(wo):
        windows, order = wo
        ov_a, hist_a = one_fleet(windows, list(range(len(windows))))
        ov_b, hist_b = one_fleet(windows, list(order))
        assert ov_a == ov_b
        assert hist_a == hist_b

    check()


# ---------------------------------------------------------------------------
# predictor rank determinism (the federated-retrain regression)
# ---------------------------------------------------------------------------

def test_predictor_tie_break_is_by_key_not_insertion_order():
    """Two successor tables with the same counts but different dict
    insertion order (exactly what differently-ordered federation merges
    produce) must predict in the same order: ties break by key."""
    fwd = {"a": {"x": 2, "y": 2, "z": 3}}
    rev = {"a": {"z": 3, "y": 2, "x": 2}}
    p_fwd = TransitionPredictor(fwd, top_k=3)
    p_rev = TransitionPredictor(rev, top_k=3)
    assert p_fwd.successors("a") == p_rev.successors("a") == ["z", "x", "y"]
    # truncation happens AFTER the deterministic sort: top-2 keeps the
    # count-3 winner plus the lexicographically-first of the tied pair
    assert TransitionPredictor(rev, top_k=2).successors("a") == ["z", "x"]
