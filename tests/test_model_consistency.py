"""Cross-path model consistency: chunked-jnp attention vs naive oracle,
decode-continuation == prefill (the KV-cache correctness invariant, per
family), chunkwise vs recurrent mLSTM, chunked vs full xent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import flash_attention_jnp
from repro.models.layers import chunked_xent, logits_from_embedding, softmax_xent
from repro.models.xlstm import mlstm_chunkwise, mlstm_scan
from repro.models.zoo import build_model
from repro.serving.engine import _graft_prefill_cache, _strip_usage

from conftest import rand_batch

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize(
    "case",
    [
        dict(Sq=128, Sk=128, causal=True, window=None),
        dict(Sq=100, Sk=100, causal=True, window=24),
        dict(Sq=64, Sk=160, causal=False, window=None),
        dict(Sq=250, Sk=250, causal=True, window=None),
    ],
)
def test_chunked_attention_vs_naive(case):
    B, H, Hkv, hd = 2, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, case["Sq"], H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, case["Sk"], Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, case["Sk"], Hkv, hd), jnp.float32)
    out = flash_attention_jnp(q, k, v, causal=case["causal"], window=case["window"],
                              chunk_q=32, chunk_k=48)
    ref = attention_ref(q, k, v, causal=case["causal"], window=case["window"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# decode-vs-prefill: run prefill on a prefix, decode the rest feeding gold
# tokens, and require the final-step logits to match a full prefill.
DECODE_PARITY_ARCHS = [
    "mistral-large-123b",  # dense GQA + SWA
    "yi-34b",              # dense GQA full attention
    "gemma3-27b",          # local:global pattern + softcap
    "mixtral-8x22b",       # MoE
    "deepseek-v2-lite-16b",  # MLA latent cache
    "recurrentgemma-9b",   # RG-LRU + local attn
    "xlstm-125m",          # mLSTM/sLSTM states
    "phi3-medium-14b",
]


@pytest.mark.parametrize("arch", DECODE_PARITY_ARCHS)
def test_decode_matches_prefill(arch, rng):
    # fp32 compute: this test checks the cache/continuation LOGIC exactly;
    # bf16 accumulation-order noise is covered by the kernel tolerances
    cfg = get_reduced(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    B, S_pre, S_full = 2, 6, 12
    tokens = jax.random.randint(rng, (B, S_full), 0, cfg.vocab_size)

    full_logits, _ = model.prefill(params, {"tokens": tokens})

    pre_logits, caches = model.prefill(params, {"tokens": tokens[:, :S_pre]})
    caches = _strip_usage(caches)
    big = model.init_cache(B, S_full + 4, multimodal=False)
    caches = _graft_prefill_cache(big, caches)
    logits = pre_logits
    for step in range(S_pre, S_full):
        db = {"tokens": tokens[:, step : step + 1], "pos": jnp.full((B,), step, jnp.int32)}
        logits, caches = model.decode_step(params, caches, db)
        caches = _strip_usage(caches)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits), atol=2e-3, rtol=2e-3
    )


def test_mlstm_chunkwise_vs_recurrent():
    # Deflake contract (ROADMAP watch item): fixed dedicated seed — this
    # test's inputs must never drift when other tests split the module KEY
    # — and a tolerance DERIVED from dtype eps instead of a magic constant.
    #
    # Both paths accumulate the same (C, n) state over S steps in float32;
    # the chunkwise path only re-associates those sums, so the paths
    # differ by a random walk over O(S) roundings of O(1)-magnitude
    # terms: ~sqrt(S)*eps relative drift typical, ~S*eps in the tail.
    # Measured over 20 seeds the worst (err - rtol*|h_ref|) was ≈30*S*eps,
    # so the 64* factor gives a >2x margin on both knobs.
    B, S, H, hd = 2, 256, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(20260729), 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd)) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (B, S, H, hd))
    li = jax.random.normal(ks[3], (B, S, H)) * 2
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S, H)) * 2)
    h_ref, (C_r, n_r, m_r) = mlstm_scan(q, k, v, li, lf)
    eps = float(np.finfo(np.asarray(h_ref).dtype).eps)
    atol = 64 * S * eps            # ≈2.0e-3 for float32, S=256
    rtol = 64 * np.sqrt(S) * eps   # ≈1.2e-4
    for chunk in (32, 64, 128):
        h_c, (C_c, n_c, m_c) = mlstm_chunkwise(q, k, v, li, lf, chunk)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref),
                                   atol=atol, rtol=rtol)
        # the m stabilizer is an exact max-plus scan (PR 2) — no float
        # accumulation at all, so allow only a couple of ulps of slack
        np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_r),
                                   atol=4 * eps, rtol=0)


def test_chunked_xent_matches_full():
    B, S, D, V = 2, 64, 32, 512
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    table = jax.random.normal(ks[1], (V, D), jnp.float32) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    full = softmax_xent(logits_from_embedding(x, table), labels)
    for chunk in (8, 16, 64):
        ch = chunked_xent(x, table, labels, chunk)
        np.testing.assert_allclose(float(ch), float(full), rtol=1e-6)


def test_pallas_path_matches_jnp_path(rng):
    for arch in ("mistral-large-123b", "recurrentgemma-9b"):
        cfg = get_reduced(arch)
        m0 = build_model(cfg.replace(use_pallas=False))
        m1 = build_model(cfg.replace(use_pallas=True))
        params = m0.init(rng)
        spec, _ = m0.train_batch_spec(2, 16)
        batch = rand_batch(rng, spec, cfg.vocab_size)
        l0, l1 = m0.loss_fn(params, batch), m1.loss_fn(params, batch)
        # Deflake: the two paths are different implementations, so their
        # accumulation orders differ; the drift scales with the LOSS
        # magnitude, and XLA:CPU's reduction partitioning varies with the
        # thread pool sized at process start (bit-identical within one
        # process, occasionally ~2x larger across runs under load).
        # Observed ≤4.5e-4 abs at loss ≈6.3; a relative bound with ~7x
        # margin replaces the old 1e-3 absolute constant that sat only
        # 2.3x above the typical drift.
        assert abs(float(l0) - float(l1)) < 5e-4 * max(1.0, abs(float(l0))), (
            arch, float(l0), float(l1))
