"""Substrate units: optimizer, compression, data pipeline, checkpoint
bundles, sharding rules, HLO cost analyzer, paper statistics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import read_bundle, write_bundle
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.optim import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    dequantize_int8,
    init_adamw,
    quantize_int8,
    warmup_cosine,
)
from repro.sharding.rules import ACT_RULES, PARAM_RULES, resolve_pspec
from repro.utils.hlocost import analyze
from repro.utils.stats import cohens_d, mann_whitney_u


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    p = {"w": jnp.array([3.0, -2.0]), "norm": jnp.array([1.5])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    st_ = init_adamw(p)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum((p["norm"] - 1.0) ** 2)
    for _ in range(100):
        p, st_ = adamw_update(cfg, jax.grad(loss)(p), st_, p)
    assert float(loss(p)) < 1e-3


def test_adamw_moments_not_aliased():
    p = {"w": jnp.zeros((8, 8))}
    s = init_adamw(p)
    assert s.m["w"].unsafe_buffer_pointer() != s.v["w"].unsafe_buffer_pointer()


def test_weight_decay_skips_1d():
    p = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    g = jax.tree.map(jnp.zeros_like, p)
    cfg = AdamWConfig(lr=1.0, weight_decay=0.5, clip_norm=0.0)
    p2, _ = adamw_update(cfg, g, init_adamw(p), p)
    assert float(jnp.abs(p2["scale"] - 1.0).max()) < 1e-6  # no decay
    assert float(jnp.abs(p2["w"] - 1.0).max()) > 0.1  # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0 * np.sqrt(10)) < 1e-3
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_schedule_shape():
    sched = warmup_cosine(1e-3, 10, 100, min_frac=0.1)
    assert float(sched(jnp.array(0))) == 0.0
    assert abs(float(sched(jnp.array(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.array(100))) == pytest.approx(1e-4, rel=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_bound(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (257,)) * 10 ** ((seed % 7) - 3)
    q, scale = quantize_int8(g)
    err = jnp.abs(dequantize_int8(q, scale) - g).max()
    # symmetric quantizer: error <= scale/2 (+ eps for clip at +-127)
    assert float(err) <= float(scale) * 0.5 + 1e-6 or float(err) <= float(scale)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_determinism_and_sharding():
    dc = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    p0 = SyntheticTokenPipeline(dc, shard=0, num_shards=2)
    p1 = SyntheticTokenPipeline(dc, shard=1, num_shards=2)
    assert np.array_equal(p0.batch_at(5)["tokens"], p0.batch_at(5)["tokens"])
    assert not np.array_equal(p0.batch_at(5)["tokens"], p1.batch_at(5)["tokens"])
    assert not np.array_equal(p0.batch_at(5)["tokens"], p0.batch_at(6)["tokens"])
    b = p0.batch_at(0)
    assert b["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_data_zipf_stats_sum_to_one():
    dc = DataConfig(vocab_size=4096, seq_len=128, global_batch=4)
    stats = SyntheticTokenPipeline(dc).vocab_row_stats(n_steps=2, row_group=512)
    assert abs(sum(stats.values()) - 1.0) < 1e-9
    # Zipf: group 0 is the hottest
    assert stats["embed#rg0"] == max(stats.values())


# ---------------------------------------------------------------------------
# checkpoint bundle
# ---------------------------------------------------------------------------


def test_bundle_partial_read(tmp_path):
    import ml_dtypes

    arrays = {
        "big": np.random.randn(256, 64).astype(np.float32),
        "bf": np.random.randn(33).astype(ml_dtypes.bfloat16),
    }
    write_bundle(str(tmp_path / "b"), arrays)
    sub = read_bundle(str(tmp_path / "b"), keys=["bf"])
    assert list(sub) == ["bf"]
    assert sub["bf"].tobytes() == arrays["bf"].tobytes()


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def test_resolve_pspec_divisibility_fallback():
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(1, 1)  # 1 device: everything divisible by 1
    spec = resolve_pspec(("vocab", "embed"), (50_000, 512), mesh, PARAM_RULES)
    assert spec is not None


def test_resolve_pspec_composite_batch():
    """batch -> ("pod","data") composes, with suffix fallback when the pod
    product doesn't divide."""
    import jax
    from jax.sharding import PartitionSpec

    # emulate resolution logic without building a 512-dev mesh: use a tiny
    # mesh with the same axis names
    devs = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    from jax.sharding import Mesh

    mesh = Mesh(devs, ("pod", "data", "model"))
    spec = resolve_pspec(("batch", "seq"), (8, 128), mesh, ACT_RULES)
    assert spec[0] == ("pod", "data")
    spec1 = resolve_pspec(("batch",), (1,), mesh, ACT_RULES)
    assert spec1 == PartitionSpec(("pod", "data"))  # 1 % 1 == 0 on tiny mesh


# ---------------------------------------------------------------------------
# loop-aware HLO cost analysis
# ---------------------------------------------------------------------------


def test_hlocost_counts_loop_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = jax.jit(f).lower(jnp.ones((128, 128)), jnp.ones((128, 128))).compile()
    cost = analyze(c.as_text())
    expect = 10 * 2 * 128**3
    assert abs(cost.dot_flops - expect) / expect < 0.01
    # raw cost_analysis undercounts by the trip count — the reason this
    # module exists
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < cost.dot_flops / 5


def test_hlocost_nested_loops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = jax.jit(f).lower(jnp.ones((64, 64)), jnp.ones((64, 64))).compile()
    cost = analyze(c.as_text())
    expect = 20 * 2 * 64**3
    assert abs(cost.dot_flops - expect) / expect < 0.01


# ---------------------------------------------------------------------------
# paper statistics (§5.1)
# ---------------------------------------------------------------------------


def test_mann_whitney_separated_samples():
    a = np.arange(20, dtype=float)
    b = np.arange(20, dtype=float) + 100
    u, p = mann_whitney_u(a, b)
    assert p < 1e-6


def test_mann_whitney_identical_samples():
    a = np.random.RandomState(0).randn(20)
    u, p = mann_whitney_u(a, a.copy())
    assert p > 0.9


def test_cohens_d_magnitudes():
    rs = np.random.RandomState(1)
    a = rs.randn(200)
    assert abs(cohens_d(a, a + 0.8)) > 0.7  # large effect
    assert abs(cohens_d(a, a + 0.01)) < 0.1  # negligible


# ---------------------------------------------------------------------------
# debug mesh validation (DESIGN.md §15.1)
# ---------------------------------------------------------------------------


def test_debug_mesh_rejects_oversized_geometry():
    """Requesting more mesh devices than the platform exposes must fail
    with the actionable XLA_FLAGS hint, not jax's opaque reshape error
    (tests run with exactly 1 CPU device — see conftest)."""
    from repro.launch.mesh import make_debug_mesh

    have = jax.device_count()
    with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
        make_debug_mesh(have + 1, 1)
    with pytest.raises(ValueError, match=rf"needs {have * 4} devices but only {have}"):
        make_debug_mesh(2 * have, 2)
    with pytest.raises(ValueError, match="must be >= 1"):
        make_debug_mesh(0, 1)
    # the degenerate geometry that always fits still builds
    m = make_debug_mesh(1, 1)
    assert m.axis_names == ("data", "model")


def test_spec_shard_divisor():
    """Divisor = product of named mesh-axis sizes; None entries and
    unknown axes contribute nothing (a replicated spec divides by 1)."""
    from types import SimpleNamespace

    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import spec_shard_divisor

    mesh = SimpleNamespace(axis_names=("data", "model"), devices=np.zeros((2, 4)))
    assert spec_shard_divisor(P(), mesh) == 1
    assert spec_shard_divisor(P(None, "model"), mesh) == 4
    assert spec_shard_divisor(P("data", "model"), mesh) == 8
    assert spec_shard_divisor(P(("data", "model"),), mesh) == 8
    assert spec_shard_divisor(P("nonexistent"), mesh) == 1
