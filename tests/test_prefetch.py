"""Prefetch + residency/eviction subsystem (DESIGN.md §8).

Covers the acceptance contract of the residency layer:
  * prefetch-hit vs. fault-in parity — a unit loaded via the prefetcher's
    staging pipeline lands byte-identical to one faulted synchronously;
  * eviction-under-budget invariant — resident bytes never exceed the
    device budget while victims are evictable (high-water asserted);
  * pins block eviction until released; evicted units refault correctly;
  * demand ensure() waits out an in-flight prefetch instead of re-reading;
  * a threaded stress of concurrent ensure()/evict/hint stays consistent;
  * end-to-end generation under a budget below tier-1 size matches the
    full baseline and never exceeds the budget.
"""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.entrypoints import SERVING_PROFILE, DeploymentProfile
from repro.core.on_demand import TieredParams
from repro.core.optional_store import OptionalStore, write_store
from repro.core.partition import TierDecision, TierPlan, Unit, _expert_units, _row_units
from repro.core.prefetch import Prefetcher

ROWS, COLS, N_UNITS = 16, 32, 8
UNIT_BYTES = ROWS * COLS * 4


def _mini(tmp_path, budget=None, name="mini"):
    """A one-leaf tiered param tree with N_UNITS row-group units backed by
    a real optional store — the loader state machine without a model."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal((N_UNITS * ROWS, COLS)).astype(np.float32)
    units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * ROWS, (g + 1) * ROWS), nbytes=UNIT_BYTES)
        for g in range(N_UNITS)
    )
    dec = TierDecision("emb", 1, "rows", "test", data.nbytes, units=units)
    plan = TierPlan({"emb": dec}, SERVING_PROFILE, [])
    path = str(tmp_path / f"{name}.blob")
    write_store(path, [(u.key, data[u.rows[0]: u.rows[1]]) for u in units])
    tp = TieredParams(
        {"emb": jnp.zeros(data.shape, jnp.float32)}, plan, OptionalStore(path),
        device_budget_bytes=budget,
    )
    return tp, data, units


def _leaf_rows(tp, unit):
    lo, hi = unit.rows
    return np.asarray(tp.leaf("emb"))[lo:hi]


# ---------------------------------------------------------------------------
# unit cost metadata
# ---------------------------------------------------------------------------

def test_unit_nbytes_partition_metadata():
    itemsize = 4
    shape = (3, 4, 8, 16)  # (layers, experts, d1, d2)
    eu = _expert_units("w", shape, 1, itemsize)
    assert len(eu) == 12
    assert all(u.nbytes == 8 * 16 * itemsize for u in eu)
    assert sum(u.nbytes for u in eu) == int(np.prod(shape)) * itemsize

    ru = _row_units("emb", 100, 32, 7)
    assert [u.nbytes for u in ru] == [32 * 7, 32 * 7, 32 * 7, 4 * 7]
    assert sum(u.nbytes for u in ru) == 100 * 7


# ---------------------------------------------------------------------------
# prefetch-hit vs fault-in parity
# ---------------------------------------------------------------------------

def test_prefetch_hit_matches_fault_in(tmp_path):
    tp_fault, data, units = _mini(tmp_path, name="fault")
    tp_pf, _, _ = _mini(tmp_path, name="pf")

    key = units[2].key
    moved_fault = tp_fault.ensure([key])
    assert moved_fault == UNIT_BYTES

    pf = Prefetcher(tp_pf, batch_units=2)
    try:
        assert pf.hint([key]) == 1
        assert pf.drain(10.0)
        moved_hit = tp_pf.ensure([key])  # demand touch: prefetch hit
    finally:
        pf.stop()
    assert moved_hit == 0
    assert tp_pf.stats.prefetch_hits == 1
    assert tp_pf.stats.misses == 0
    # loaded bytes identical either way — accounting and content
    ev_fault = [e for e in tp_fault.stats.events if e.key == key]
    ev_pf = [e for e in tp_pf.stats.events if e.key == key]
    assert ev_fault[0].nbytes == ev_pf[0].nbytes == UNIT_BYTES
    assert ev_pf[0].source == "prefetch" and ev_fault[0].source == "fault"
    np.testing.assert_array_equal(_leaf_rows(tp_fault, units[2]), _leaf_rows(tp_pf, units[2]))
    np.testing.assert_array_equal(_leaf_rows(tp_pf, units[2]), data[32:48])


def test_hint_drops_resident_and_duplicate_keys(tmp_path):
    tp, _, units = _mini(tmp_path)
    tp.ensure([units[0].key])
    pf = Prefetcher(tp, batch_units=4)
    try:
        accepted = pf.hint([units[0].key, units[1].key, units[1].key])
        assert accepted == 1  # resident and duplicate hints dropped
        assert pf.drain(10.0)
    finally:
        pf.stop()
    assert tp.is_resident(units[1].key)
    assert pf.stats.skipped_resident == 2


# ---------------------------------------------------------------------------
# eviction under budget
# ---------------------------------------------------------------------------

def test_eviction_under_budget_invariant(tmp_path):
    budget = 3 * UNIT_BYTES
    tp, data, units = _mini(tmp_path, budget=budget)
    for u in units:
        tp.ensure([u.key])
    res = tp.residency
    assert res.max_resident_bytes <= budget
    assert res.resident_bytes == len(res.resident_keys) * UNIT_BYTES
    assert len(res.resident_keys) == 3
    assert tp.stats.evictions == N_UNITS - 3
    assert res.overshoot_events == 0
    # LRU: the last three ensured units are the residents
    assert res.resident_keys == {u.key for u in units[-3:]}
    # evicted slices are placeholder zeros again
    for u in units[:3]:
        np.testing.assert_array_equal(_leaf_rows(tp, u), np.zeros((ROWS, COLS), np.float32))
    # refault of an evicted unit restores exact content
    tp.ensure([units[0].key])
    assert tp.stats.refaults == 1
    np.testing.assert_array_equal(_leaf_rows(tp, units[0]), data[:ROWS])
    assert res.max_resident_bytes <= budget


def test_touch_refreshes_lru_order(tmp_path):
    budget = 2 * UNIT_BYTES
    tp, _, units = _mini(tmp_path, budget=budget)
    tp.ensure([units[0].key])
    tp.ensure([units[1].key])
    tp.ensure([units[0].key])  # touch: unit 0 becomes MRU
    tp.ensure([units[2].key])  # evicts unit 1, not unit 0
    assert tp.residency.resident_keys == {units[0].key, units[2].key}


def test_select_victims_batch_ties_deterministic(tmp_path):
    """Units committed by one ensure() batch share a logical-clock stamp;
    the victim order among them must be the key order, not whatever
    dict-insertion order the batch happened to load in (regression: tied
    LRU timestamps from batched commits were insertion-dependent)."""
    tp_a, _, units = _mini(tmp_path, name="a")
    tp_b, _, _ = _mini(tmp_path, name="b")
    batch = [units[3].key, units[1].key, units[2].key]
    tp_a.ensure(batch)                  # one batch -> one stamp for all 3
    tp_b.ensure(list(reversed(batch)))  # same batch, opposite insertion order
    for tp in (tp_a, tp_b):
        stamps = {k: tp.residency._stamp[k] for k in batch}
        assert len(set(stamps.values())) == 1, stamps
        # tie broken by key: insertion order must not matter
        assert tp.residency.select_victims(UNIT_BYTES) == [units[1].key]
        assert tp.residency.select_victims(2 * UNIT_BYTES) == [
            units[1].key, units[2].key]
    # a later batch is younger: victims still come from the old batch first
    tp_a.ensure([units[0].key])
    assert tp_a.residency.select_victims(4 * UNIT_BYTES) == [
        units[1].key, units[2].key, units[3].key, units[0].key]
    # and a touch re-stamps: the touched member of the tie survives longest
    tp_a.touch([units[1].key])
    assert tp_a.residency.select_victims(2 * UNIT_BYTES) == [
        units[2].key, units[3].key]


def test_pin_blocks_eviction_until_release(tmp_path):
    budget = 2 * UNIT_BYTES
    tp, _, units = _mini(tmp_path, budget=budget)
    tp.ensure([units[0].key, units[1].key], pin=True)
    tp.ensure([units[2].key])  # nothing evictable: overshoot, pins survive
    assert tp.is_resident(units[0].key) and tp.is_resident(units[1].key)
    assert tp.residency.overshoot_events == 1
    tp.release([units[0].key, units[1].key])
    tp.ensure([units[3].key])  # now eviction can make room
    assert tp.residency.resident_bytes <= budget
    assert tp.stats.evictions >= 2


def test_release_reclaims_overshoot_without_new_installs(tmp_path):
    """A pinned step that overshot the budget must be reclaimed at
    release() even if no further install ever triggers eviction."""
    budget = 2 * UNIT_BYTES
    tp, _, units = _mini(tmp_path, budget=budget)
    pinned = [u.key for u in units[:5]]
    tp.ensure(pinned, pin=True)  # 5 units resident, all pinned: overshoot
    assert tp.residency.resident_bytes == 5 * UNIT_BYTES
    tp.release(pinned)  # no subsequent ensure — reclaim happens here
    assert tp.residency.resident_bytes <= budget
    assert tp.stats.evictions == 3


def test_mid_batch_load_failure_aborts_all_claims(tmp_path):
    """A fetch error must roll back every still-LOADING claim in the
    batch, or later ensure() calls would hang then silently no-op."""
    tp, _, units = _mini(tmp_path)
    bad, good = units[0].key, units[1].key
    # corrupt the first unit's offset so it sorts first and its read raises
    tp.store.entries[bad].offset = -1

    with pytest.raises(Exception):
        tp.ensure([bad, good])
    assert tp.residency.state_of(bad) == "cold"
    assert tp.residency.state_of(good) == "cold"
    # the unaffected key loads fine afterwards (no stuck LOADING state)
    assert tp.ensure([good]) == UNIT_BYTES


def test_ensure_waits_for_inflight_prefetch(tmp_path):
    tp, data, units = _mini(tmp_path)
    key = units[4].key
    assert tp.claim_for_prefetch(key)

    def finish():
        time.sleep(0.15)
        arr = tp.store.fetch(key)
        tp.install_prefetched(key, arr)

    t = threading.Thread(target=finish)
    t.start()
    moved = tp.ensure([key])  # must block on the in-flight load, not re-read
    t.join()
    assert moved == 0
    assert tp.stats.prefetch_waits == 1
    assert tp.stats.misses == 0
    np.testing.assert_array_equal(_leaf_rows(tp, units[4]), data[4 * ROWS: 5 * ROWS])


def test_ensure_takes_over_aborted_prefetch(tmp_path):
    tp, data, units = _mini(tmp_path)
    key = units[5].key
    assert tp.claim_for_prefetch(key)

    def bail():
        time.sleep(0.1)
        tp.abort_prefetch(key)

    t = threading.Thread(target=bail)
    t.start()
    moved = tp.ensure([key])  # waiter takes over the load after the abort
    t.join()
    assert moved == UNIT_BYTES
    assert tp.is_resident(key)
    np.testing.assert_array_equal(_leaf_rows(tp, units[5]), data[5 * ROWS: 6 * ROWS])


# ---------------------------------------------------------------------------
# threaded stress: concurrent ensure / evict / hint
# ---------------------------------------------------------------------------

def test_threaded_ensure_evict_stress(tmp_path):
    budget = 4 * UNIT_BYTES
    tp, data, units = _mini(tmp_path, budget=budget)
    keys = [u.key for u in units]
    errors = []
    stop = threading.Event()

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(40):
                pick = list(rng.choice(keys, size=rng.integers(1, 4), replace=False))
                tp.ensure(pick)
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    def evictor():
        rng = np.random.default_rng(99)
        try:
            while not stop.is_set():
                tp.evict([rng.choice(keys)])
                time.sleep(0.001)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    pf = Prefetcher(tp, batch_units=3)
    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    ev = threading.Thread(target=evictor)
    ev.start()
    for t in threads:
        t.start()
    for i in range(20):
        pf.hint([keys[i % len(keys)]])
    for t in threads:
        t.join()
    stop.set()
    ev.join()
    pf.drain(10.0)
    pf.stop()

    assert not errors, errors
    res = tp.residency
    # no pins were taken → the budget was never exceeded
    assert res.max_resident_bytes <= budget
    # bookkeeping is exact: charged bytes == sum over resident units
    resident = res.resident_keys
    assert res.resident_bytes == len(resident) * UNIT_BYTES
    # device contents match the store for residents, zeros for cold units
    for u in units:
        expect = data[u.rows[0]: u.rows[1]] if u.key in resident else np.zeros((ROWS, COLS), np.float32)
        np.testing.assert_array_equal(_leaf_rows(tp, u), expect)


# ---------------------------------------------------------------------------
# end-to-end: generation under a device budget with prefetch
# ---------------------------------------------------------------------------

def test_generation_under_budget_matches_full(tmp_path):
    from repro.configs import get_reduced
    from repro.core import analyze, build_artifact, write_monolithic
    from repro.models.zoo import build_model
    from repro.optim import init_adamw
    from repro.serving import GenerationEngine, cold_start

    arch = "yi-34b"
    cfg = get_reduced(arch)
    model = build_model(cfg)
    # fine row-groups so a step's pinned working set stays far below budget
    profile = DeploymentProfile(hot_vocab_fraction=0.1, min_tier1_bytes=1024,
                                vocab_row_group=32)
    res = analyze(model, profile, trace_B=1, trace_S=8)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    outdir = str(tmp_path)
    write_monolithic({"params": params, "opt_state": {"m": opt.m, "v": opt.v}}, outdir)
    build_artifact(params, res, outdir)

    tier1 = res.plan.tier1_bytes
    budget = tier1 // 2
    assert budget < tier1

    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 4), 0, cfg.vocab_size)
    s_full = cold_start(model, outdir, None, mode="before", warm_shapes=((1, 4),))
    out_full, _ = GenerationEngine(s_full, max_seq=24).generate(toks, 4)

    s = cold_start(model, outdir, res, mode="after2", warm_shapes=((1, 4),),
                   device_budget_bytes=budget, prefetch=True)
    try:
        eng = GenerationEngine(s, max_seq=24)
        out1, st1 = eng.generate(toks, 4)
        out2, st2 = eng.generate(toks, 4)
    finally:
        s.close()

    np.testing.assert_array_equal(out_full, out1)
    np.testing.assert_array_equal(out_full, out2)
    # the acceptance invariant: resident bytes never exceeded the budget
    assert s.tiered.residency.max_resident_bytes <= budget
    assert s.tiered.resident_bytes <= budget
    assert st1.faulted_units > 0  # it really ran cold
    # step accounting counts the prefill-produced token too — faults/step
    # metrics must divide by n_steps, not n_steps - 1
    assert st1.steps == st2.steps == 4


def test_residency_preset_strict_budget(tmp_path):
    from repro.configs import get_reduced
    from repro.core import analyze, build_artifact
    from repro.models.zoo import build_model
    from repro.serving import RESIDENCY_PRESETS, cold_start

    arch = "yi-34b"
    cfg = get_reduced(arch)
    model = build_model(cfg)
    profile = DeploymentProfile(hot_vocab_fraction=0.1, min_tier1_bytes=1024,
                                vocab_row_group=32)
    res = analyze(model, profile, trace_B=1, trace_S=8)
    params = model.init(jax.random.PRNGKey(0))
    build_artifact(params, res, str(tmp_path))

    s = cold_start(model, str(tmp_path), res, mode="after2", warm_shapes=((1, 4),),
                   compile_warm_set=False, residency="strict")
    try:
        frac, want_prefetch = RESIDENCY_PRESETS["strict"]
        assert s.prefetcher is None if not want_prefetch else s.prefetcher is not None
        budget = s.tiered.residency.budget_bytes
        assert budget is not None and budget < res.plan.tier1_bytes
        # loading everything still respects the budget (evicts as it goes)
        s.tiered.ensure_all()
        assert s.tiered.residency.max_resident_bytes <= budget
    finally:
        s.close()

    with pytest.raises(ValueError):
        cold_start(model, str(tmp_path), res, mode="after2", residency="bogus")
