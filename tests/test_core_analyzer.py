"""FaaSLight core tests: reachability exactness, tier partitioning rules,
file elimination, optional store roundtrip, on-demand fault-in."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (
    DeploymentProfile,
    OptionalStore,
    TieredParams,
    analyze,
    build_artifact,
    build_reachability,
    eliminate_collections,
    write_monolithic,
)
from repro.core.optional_store import OptionalStoreWriter
from repro.models.zoo import build_model
from repro.utils.tree import flatten_with_paths


# ---------------------------------------------------------------------------
# param_graph: exact graph-level reachability
# ---------------------------------------------------------------------------


def test_whisper_decode_never_reaches_encoder():
    model = build_model(get_reduced("whisper-base"))
    rep = build_reachability(model.entries(B=1, S=8), model.abstract())
    for p, entries in rep.reachable.items():
        if p.startswith("encoder"):
            assert "decode_step" not in entries, p
            assert "prefill" in entries  # but audio prefill does reach it
        elif p == "embed":
            assert "decode_step" in entries


def test_vlm_text_only_never_reaches_cross_attn():
    model = build_model(get_reduced("llama-3.2-vision-90b"))
    rep = build_reachability(model.entries(B=1, S=8), model.abstract())
    for p, entries in rep.reachable.items():
        if ".cross." in p:
            assert not any(e.endswith("_text_only") for e in entries), (p, entries)


def test_decode_does_not_reach_kv_projections_of_cross_attn():
    """Decode reads cached xk/xv, so wk/wv of VLM cross-attn are dead even
    for multimodal decode — a strictly finer result than file-level DCE."""
    model = build_model(get_reduced("llama-3.2-vision-90b"))
    rep = build_reachability(model.entries(B=1, S=8), model.abstract())
    wk = [p for p in rep.reachable if ".cross.wk" in p]
    assert wk
    for p in wk:
        assert "decode_step" not in rep.reachable[p]


def test_remat_does_not_defeat_precision():
    cfg = get_reduced("llama-3.2-vision-90b")
    for remat in ("none", "full"):
        model = build_model(cfg.replace(remat=remat))
        rep = build_reachability(
            [e for e in model.entries(B=1, S=8) if e.name == "prefill_text_only"],
            model.abstract(),
        )
        dead = {p for p, s in rep.reachable.items() if not s}
        assert any(".cross." in p for p in dead), remat


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------


def _profile(**kw):
    base = dict(resident_experts=1, hot_vocab_fraction=0.25,
                min_tier1_bytes=1024, vocab_row_group=128)
    base.update(kw)
    return DeploymentProfile(**base)


def test_tier_plan_moe():
    model = build_model(get_reduced("mixtral-8x22b"))
    res = analyze(model, _profile(), trace_B=1, trace_S=16)
    plan = res.plan
    for p, d in plan.decisions.items():
        if "moe.w_" in p:
            assert d.tier == 1 and d.granularity == "expert", p
            # per-(layer, expert) units; resident_experts=1 per layer
            n_layers, n_exp = 2, 4
            assert len(d.units) == n_layers * n_exp
            assert len(d.resident_units) == n_layers * 1
        if p.endswith("router"):
            assert d.tier == 0, "router must stay resident"
    assert 0.0 < plan.tier0_fraction < 1.0
    assert plan.cold_resident_bytes < plan.total_bytes


def test_tier_plan_small_leaves_resident():
    model = build_model(get_reduced("yi-34b"))
    res = analyze(model, _profile(min_tier1_bytes=1 << 30), trace_B=1, trace_S=16)
    # with a huge min size, everything is tier-0
    assert all(d.tier == 0 for d in res.plan.decisions.values())


def test_tier_plan_training_profile_keeps_all():
    from repro.core import TRAINING_PROFILE

    model = build_model(get_reduced("mixtral-8x22b"))
    res = analyze(model, TRAINING_PROFILE, trace_B=1, trace_S=16)
    assert all(d.tier == 0 for d in res.plan.decisions.values())


def test_file_elimination():
    collections = {
        "params": {"w": np.zeros((4, 4), np.float32)},
        "opt_state": {"m": np.zeros((4, 4), np.float32), "v": np.zeros((4, 4), np.float32)},
        "ema": {"w": np.zeros((4, 4), np.float32)},
    }
    kept, report = eliminate_collections(collections)
    assert set(kept) == {"params"}
    assert report.dropped_bytes == 3 * 64
    kept_t, report_t = eliminate_collections(collections, for_training=True)
    assert set(kept_t) == set(collections)


# ---------------------------------------------------------------------------
# optional store ("lightweight file")
# ---------------------------------------------------------------------------


def test_store_roundtrip_dtypes(tmp_path):
    import ml_dtypes

    path = str(tmp_path / "o.blob")
    arrays = {
        "a": np.random.randn(32, 16).astype(np.float32),
        "b": np.random.randn(64).astype(ml_dtypes.bfloat16),
        "c": np.arange(100, dtype=np.int32),
    }
    with OptionalStoreWriter(path) as w:
        for k, v in arrays.items():
            w.add(k, v)
    store = OptionalStore(path)
    for k, v in arrays.items():
        got = store.fetch(k)
        assert got.dtype == v.dtype and got.shape == v.shape
        assert np.ascontiguousarray(got).tobytes() == v.tobytes()
    assert store.compressed_bytes <= store.raw_bytes * 1.1


def test_store_compression_byteplane(tmp_path):
    """bf16 weights compress meaningfully (byte-planed exponent bytes)."""
    import ml_dtypes

    path = str(tmp_path / "o.blob")
    w = (np.random.randn(512, 256) * 0.02).astype(ml_dtypes.bfloat16)
    with OptionalStoreWriter(path) as wr:
        wr.add("w", w)
    store = OptionalStore(path)
    assert store.compressed_bytes < 0.9 * store.raw_bytes


def test_store_atomicity(tmp_path):
    path = str(tmp_path / "o.blob")
    try:
        with OptionalStoreWriter(path) as w:
            w.add("x", np.zeros(4, np.float32))
            raise RuntimeError("crash mid-build")
    except RuntimeError:
        pass
    assert not os.path.exists(path)
    assert not os.path.exists(path + ".partial")


# ---------------------------------------------------------------------------
# artifact + on-demand fault-in
# ---------------------------------------------------------------------------


def test_artifact_and_fault_in(tmp_path, rng):
    cfg = get_reduced("mixtral-8x22b")
    model = build_model(cfg)
    res = analyze(model, _profile(), trace_B=1, trace_S=16)
    params = model.init(rng)
    meta = build_artifact(params, res, str(tmp_path))
    assert meta["tier1_compressed_bytes"] <= meta["tier1_raw_bytes"]

    store = OptionalStore(str(tmp_path / "optional.blob"))
    flat = dict(flatten_with_paths(params))
    # zeroed placeholders for tier-1
    from repro.utils.tree import tree_from_flat

    lf = dict(flat)
    tier1 = [p for p, d in res.plan.decisions.items() if d.tier == 1]
    for p in tier1:
        lf[p] = jnp.zeros_like(lf[p])
    tp = TieredParams(tree_from_flat(lf), res.plan, store)

    key = "groups.u0.moe.w_up#l1e3"
    ref = np.asarray(flat["groups.u0.moe.w_up"])[1, 3]
    moved = tp.ensure([key])
    assert moved == ref.nbytes
    got = np.asarray(tp.leaf("groups.u0.moe.w_up"))[1, 3]
    np.testing.assert_array_equal(got, ref)
    assert tp.ensure([key]) == 0  # idempotent
    assert tp.stats.misses == 1

    # full hydration == original params
    tp.ensure_all()
    for p in tier1:
        np.testing.assert_array_equal(np.asarray(tp.leaf(p)), np.asarray(flat[p]))


def test_monolithic_baselines(tmp_path, rng):
    cfg = get_reduced("yi-34b")
    model = build_model(cfg)
    params = model.init(rng)
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    p_before = write_monolithic({"params": params, "opt_state": opt}, str(tmp_path))
    p_after1 = write_monolithic({"params": params, "opt_state": opt}, str(tmp_path), pruned=True)
    assert os.path.getsize(p_before) > os.path.getsize(p_after1)
