"""Profile-guided re-tiering subsystem (DESIGN.md §11).

Covers the acceptance contract of the telemetry → replan → rewrite loop:
  * the access trace records faults/touches/phases/pairs/transitions and
    round-trips through JSON deterministically (record → JSON → replan
    yields byte-identical plans);
  * the replanner promotes demand-faulted units into the hot set, demotes
    never-touched residents, and respects the promotion byte budget;
  * the tier-0 ⊇ entry-reachable invariant survives adversarial traces —
    no trace content can demote a reachable tier-0 leaf;
  * ``retier_artifact`` moves bytes between the tier-0 bundle and the
    optional store exactly (content verified both directions) and commits
    via rename;
  * the ``TransitionPredictor`` ranks successors deterministically and
    ``Prefetcher.observe`` turns observations into ahead-of-schedule loads.
"""

import json
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import tensorstore_lite as tsl
from repro.core import (
    AccessTrace,
    DeploymentProfile,
    OptionalStore,
    Prefetcher,
    TieredParams,
    TransitionPredictor,
    analyze,
    build_artifact,
    check_tier0_superset,
    replan_from_trace,
    required_tier0,
    retier_artifact,
)
from repro.core.entrypoints import SERVING_PROFILE
from repro.core.on_demand import LoadEvent
from repro.core.optional_store import write_store
from repro.core.param_graph import ReachabilityReport
from repro.core.partition import TierDecision, TierPlan, Unit

ROWS, COLS, N_UNITS = 16, 32, 8
UNIT_BYTES = ROWS * COLS * 4


def _mini(tmp_path, budget=None, name="mini", resident=()):
    """A one-leaf tiered param tree with N_UNITS row-group units backed by
    a real optional store (the loader state machine without a model)."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal((N_UNITS * ROWS, COLS)).astype(np.float32)
    units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * ROWS, (g + 1) * ROWS), nbytes=UNIT_BYTES)
        for g in range(N_UNITS)
    )
    dec = TierDecision("emb", 1, "rows", "test", data.nbytes, units=units,
                       resident_units=tuple(resident))
    plan = TierPlan({"emb": dec}, SERVING_PROFILE, [])
    path = str(tmp_path / f"{name}.blob")
    write_store(path, [(u.key, data[u.rows[0]: u.rows[1]]) for u in units])
    tp = TieredParams(
        {"emb": jnp.zeros(data.shape, jnp.float32)}, plan, OptionalStore(path),
        device_budget_bytes=budget,
    )
    return tp, data, units, plan


def _reach(paths_reaching: dict) -> ReachabilityReport:
    return ReachabilityReport(
        entry_names=["prefill", "decode_step"],
        reachable={p: set(s) for p, s in paths_reaching.items()},
    )


# ---------------------------------------------------------------------------
# trace recording + serialization
# ---------------------------------------------------------------------------

def test_trace_records_faults_touches_phases(tmp_path):
    tp, _, units, _ = _mini(tmp_path)
    trace = tp.start_trace()
    k = [u.key for u in units]

    tp.set_phase("prefill")
    tp.ensure([k[0], k[1]])          # both cold
    tp.set_phase("decode")
    tp.ensure([k[1], k[2]])          # k1 warm touch, k2 cold

    assert trace.batches == 2
    assert trace.faults == {k[0]: 1, k[1]: 1, k[2]: 1}
    assert trace.touches == {k[0]: 1, k[1]: 2, k[2]: 1}
    assert trace.phases[k[0]] == {"prefill": 1}
    assert trace.phases[k[2]] == {"decode": 1}
    # co-access pairs within each batch, transitions across batches
    assert trace.pairs == {(k[0], k[1]): 1, (k[1], k[2]): 1}
    assert trace.transitions[k[0]] == {k[1]: 1, k[2]: 1}
    # preload/prefetch sources never pollute the demand trace
    tp.ensure([k[3]], source="preload")
    assert k[3] not in trace.faults
    # phase tags ride the load events too
    phases = {e.key: e.phase for e in tp.stats.events if e.source == "fault"}
    assert phases[k[0]] == "prefill" and phases[k[2]] == "decode"


def test_trace_assoc_batch_cap(tmp_path):
    trace = AccessTrace(max_assoc_batch=2)
    trace.record(["a", "b", "c"], ["a"], "prefill")  # over cap: no pairs
    trace.record(["d"], ["d"], "decode")
    assert trace.pairs == {}
    assert trace.transitions == {}  # prior batch was over-cap, link dropped
    assert trace.faults == {"a": 1, "d": 1}  # counts still exact


def test_trace_json_roundtrip_deterministic(tmp_path):
    tp, _, units, _ = _mini(tmp_path)
    trace = tp.start_trace()
    rng = np.random.default_rng(7)
    keys = [u.key for u in units]
    for i in range(12):
        pick = list(rng.choice(keys, size=rng.integers(1, 4), replace=False))
        tp.set_phase("prefill" if i % 3 == 0 else "decode")
        tp.ensure(pick)

    s1 = trace.to_json()
    rt = AccessTrace.from_json(s1)
    assert rt.to_json() == s1
    # save/load is the same document
    p = str(tmp_path / "trace.json")
    trace.save(p)
    assert AccessTrace.load(p).to_json() == s1
    with open(p) as f:
        assert json.load(f)["version"] == AccessTrace.VERSION


# ---------------------------------------------------------------------------
# replanner: promotion, demotion, determinism
# ---------------------------------------------------------------------------

def test_replan_promotes_faulted_demotes_untouched(tmp_path):
    tp, _, units, plan = _mini(tmp_path)
    # hand-build residents: rg0 and rg1 preloaded
    keys = [u.key for u in units]
    plan.decisions["emb"] = TierDecision(
        "emb", 1, "rows", "test", plan.decisions["emb"].nbytes,
        units=units, resident_units=(keys[0], keys[1]),
    )
    trace = AccessTrace()
    trace.record([keys[0], keys[4]], [keys[4]], "prefill")  # rg0 touched, rg4 faults
    trace.record([keys[5]], [keys[5]], "decode")            # rg5 faults
    reach = _reach({"emb": {"prefill"}})

    new_plan, rep = replan_from_trace(plan, trace, reach)
    res = new_plan.decisions["emb"].resident_units
    assert keys[0] in res          # touched resident kept
    assert keys[1] not in res      # never touched: demoted from the hot set
    assert keys[4] in res and keys[5] in res  # faulted: promoted
    assert rep.demoted_resident == [keys[1]]
    assert set(rep.promoted_resident) == {keys[4], keys[5]}
    # tier-1 units themselves are untouched (only hot-set membership moved)
    assert new_plan.decisions["emb"].units == units

    # empty trace: demotion disabled (a misconfigured profile run must not
    # wipe the offline-stats hot set)
    new_plan2, _ = replan_from_trace(plan, AccessTrace(), reach)
    assert new_plan2.decisions["emb"].resident_units == (keys[0], keys[1])


def test_replan_promotion_budget_hottest_first(tmp_path):
    tp, _, units, plan = _mini(tmp_path)
    keys = [u.key for u in units]
    trace = AccessTrace()
    for _ in range(3):
        trace.record([keys[2]], [keys[2]], "decode")  # hottest
    trace.record([keys[5]], [keys[5]], "decode")
    trace.record([keys[6]], [keys[6]], "decode")
    reach = _reach({"emb": {"prefill"}})

    new_plan, rep = replan_from_trace(
        plan, trace, reach, max_promote_bytes=UNIT_BYTES
    )
    assert new_plan.decisions["emb"].resident_units == (keys[2],)
    assert rep.budget_skipped == 2
    assert rep.promoted_bytes == UNIT_BYTES


def test_replan_deterministic_record_json_replan(tmp_path):
    tp, _, units, plan = _mini(tmp_path)
    trace = tp.start_trace()
    rng = np.random.default_rng(23)
    keys = [u.key for u in units]
    for _ in range(10):
        tp.ensure(list(rng.choice(keys, size=rng.integers(1, 4), replace=False)))
    reach = _reach({"emb": {"prefill"}})

    p1, _ = replan_from_trace(plan, trace, reach)
    p2, _ = replan_from_trace(plan, trace, reach)
    p3, _ = replan_from_trace(plan, AccessTrace.from_json(trace.to_json()), reach)
    assert p1.decisions == p2.decisions == p3.decisions


# ---------------------------------------------------------------------------
# the tier-0 ⊇ entry-reachable invariant, adversarially
# ---------------------------------------------------------------------------

def test_tier0_superset_invariant_adversarial_traces():
    cfg_arch = "yi-34b"
    from repro.configs import get_reduced
    from repro.models.zoo import build_model

    model = build_model(get_reduced(cfg_arch))
    profile = DeploymentProfile(hot_vocab_fraction=0.1, min_tier1_bytes=1024,
                                vocab_row_group=32)
    result = analyze(model, profile, trace_B=1, trace_S=8)
    plan, reach = result.plan, result.reach
    required = required_tier0(plan, reach)
    assert required  # a real serving plan pins real leaves

    all_keys = [u.key for u in plan.all_tier1_units()]
    tier0_paths = [p for p, d in plan.decisions.items() if d.tier == 0]
    rng = np.random.default_rng(3)

    adversarial = []
    # 1. empty trace
    adversarial.append(AccessTrace())
    # 2. fabricated keys with huge counts
    t = AccessTrace()
    t.record([f"ghost#{i}" for i in range(5)], [f"ghost#{i}" for i in range(5)], "x")
    t.faults = {k: 10**9 for k in t.faults}
    adversarial.append(t)
    # 3. a trace claiming tier-0 leaves faulted (impossible in reality, but
    #    the replanner must not act on it)
    t = AccessTrace()
    t.record(tier0_paths[:8], tier0_paths[:8], "decode")
    adversarial.append(t)
    # 4. random junk over real unit keys
    for seed in range(3):
        t = AccessTrace()
        r = np.random.default_rng(seed)
        for _ in range(20):
            pick = list(r.choice(all_keys, size=r.integers(1, 5), replace=False))
            t.record(pick, pick, r.choice(["prefill", "decode", ""]))
        adversarial.append(t)

    for trace in adversarial:
        new_plan, _ = replan_from_trace(plan, trace, reach)
        check_tier0_superset(new_plan, required)  # and replan self-checked
        for p in required:
            assert new_plan.decisions[p].tier == 0

    # the checker itself trips on a hand-broken plan
    broken = dict(plan.decisions)
    victim = sorted(required)[0]
    d = broken[victim]
    broken[victim] = TierDecision(victim, 1, "leaf", "broken", d.nbytes,
                                  units=(Unit(victim, victim, nbytes=d.nbytes),))
    with pytest.raises(ValueError, match="invariant"):
        check_tier0_superset(TierPlan(broken, plan.profile, plan.entry_names), required)


# ---------------------------------------------------------------------------
# artifact rewrite: bytes move exactly, commit is atomic-by-rename
# ---------------------------------------------------------------------------

def test_retier_artifact_moves_bytes_exactly(tmp_path):
    rng = np.random.default_rng(1)
    params = {
        "a": rng.standard_normal((8, 8)).astype(np.float32),      # tier-0 dense
        "emb": rng.standard_normal((64, 4)).astype(np.float32),   # tier-1 rows
        "mod": rng.standard_normal((16, 4)).astype(np.float32),   # tier-1 leaf
    }
    row_units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * 16, (g + 1) * 16), nbytes=16 * 4 * 4)
        for g in range(4)
    )
    decisions = {
        "a": TierDecision("a", 0, "leaf", "dense", params["a"].nbytes),
        "emb": TierDecision("emb", 1, "rows", "rows", params["emb"].nbytes,
                            units=row_units, resident_units=(row_units[0].key,)),
        "mod": TierDecision("mod", 1, "leaf", "modal", params["mod"].nbytes,
                            units=(Unit("mod", "mod", nbytes=params["mod"].nbytes),)),
    }
    plan = TierPlan(decisions, SERVING_PROFILE, ["prefill"])
    reach = _reach({"a": {"prefill"}, "emb": {"prefill"}, "mod": set()})
    result = types.SimpleNamespace(plan=plan, reach=reach, profile=SERVING_PROFILE)

    outdir = str(tmp_path / "artifact")
    build_artifact(params, result, outdir)

    # profile: "mod" and two row groups fault; the preloaded rg0 never touched
    trace = AccessTrace()
    trace.record(["mod", "emb#rg2"], ["mod", "emb#rg2"], "prefill")
    trace.record(["emb#rg3"], ["emb#rg3"], "decode")

    new_plan, rep = replan_from_trace(plan, trace, reach)
    assert "mod" in rep.promoted_leaves
    assert new_plan.decisions["mod"].tier == 0
    assert new_plan.decisions["emb"].resident_units == ("emb#rg2", "emb#rg3")

    retier_dir = str(tmp_path / "artifact-retier")
    meta = retier_artifact(outdir, new_plan, out_dir=retier_dir, report=rep)

    # promoted leaf's bytes moved into the eager bundle, content-exact
    tier0 = tsl.read_bundle(os.path.join(retier_dir, "tier0"), mmap=False)
    np.testing.assert_array_equal(tier0["mod"], params["mod"])
    np.testing.assert_array_equal(tier0["a"], params["a"])
    # the store now holds exactly the remaining tier-1 units, content-exact
    store = OptionalStore(os.path.join(retier_dir, "optional.blob"))
    assert sorted(store.keys()) == [u.key for u in row_units]
    for u in row_units:
        np.testing.assert_array_equal(
            store.fetch(u.key), params["emb"][u.rows[0]: u.rows[1]]
        )
    store.close()
    # artifact.json records the new decisions + the retier stamp
    with open(os.path.join(retier_dir, "artifact.json")) as f:
        art = json.load(f)
    assert art["decisions"]["mod"]["tier"] == 0
    assert art["retier"]["promoted_leaves"] == 1
    assert meta["decisions"]["emb"]["resident_units"] == ["emb#rg2", "emb#rg3"]
    # no partial directory left behind
    assert not os.path.exists(retier_dir + ".partial")

    # in-place rewrite is refused (reads the files it would replace)
    with pytest.raises(ValueError, match="out_dir"):
        retier_artifact(outdir, new_plan, out_dir=outdir)


# ---------------------------------------------------------------------------
# predictor + observe: ahead-of-schedule loads
# ---------------------------------------------------------------------------

def test_predictor_ranks_successors_deterministically():
    transitions = {
        "a": {"b": 3, "c": 3, "d": 1},
        "x": {"y": 2},
    }
    pred = TransitionPredictor(transitions, top_k=2)
    assert pred.successors("a") == ["b", "c"]  # count desc, key asc on ties
    assert pred.successors("missing") == []
    follow = pred.follow(["a", "x"])
    assert set(follow) == {"b", "c", "y"}
    assert "a" not in follow and "x" not in follow  # observed never predicted


def test_observe_prefetches_learned_successors(tmp_path):
    tp, data, units, _ = _mini(tmp_path)
    keys = [u.key for u in units]
    pred = TransitionPredictor({keys[0]: {keys[4]: 2, keys[5]: 1}})
    pf = Prefetcher(tp, batch_units=4, predictor=pred)
    try:
        accepted = pf.observe([keys[0]])
        assert accepted == 2
        assert pf.drain(10.0)
    finally:
        pf.stop()
    assert tp.is_resident(keys[4]) and tp.is_resident(keys[5])
    assert pf.stats.predicted == 2
    assert pf.stats.observed == 1
    lo, hi = units[4].rows
    np.testing.assert_array_equal(np.asarray(tp.leaf("emb"))[lo:hi], data[lo:hi])
    # a demand touch of the predicted unit is a prefetch hit — fully hidden
    assert tp.ensure([keys[4]]) == 0
    assert tp.stats.prefetch_hits == 1


def test_request_tagging_separates_patterns_from_coincidence(tmp_path):
    """Scheduler-aware profiling (DESIGN.md §12.3): the scheduler's unioned
    demand batches conflate requests, so batch-level ``transitions`` link
    units that merely shared a step; per-request tags (``record_request``,
    emitted by ``scheduler._emit_hints`` with slot/request ids) keep each
    request's own chain — and the predictor trained on them never learns
    the cross-request coincidence."""
    trace = AccessTrace()
    # two interleaved requests: r1 walks a→b→c, r2 walks x→y→z; every
    # scheduler step demand-ensures the UNION of the active slots' units
    for step_r1, step_r2 in ((["a"], ["x"]), (["b"], ["y"]), (["c"], ["z"])):
        union = step_r1 + step_r2
        trace.record(union, union, "decode")
        trace.record_request(1, step_r1)
        trace.record_request(2, step_r2)
    trace.end_request(1)
    trace.end_request(2)

    # batch-level transitions contain the coincidence (a→y) ...
    assert "y" in trace.transitions["a"] and "b" in trace.transitions["a"]
    assert ("a", "x") in trace.pairs  # co-resident in one step ≠ co-accessed by one request
    # ... the request-tagged fields contain only true per-request chains
    assert trace.request_transitions["a"] == {"b": 1}
    assert trace.request_transitions["x"] == {"y": 1}
    assert "y" not in trace.request_transitions["a"]
    assert trace.request_pairs == {}  # each request touched one unit per step

    # a predictor built from request transitions follows the request's own
    # chain instead of fanning out across coincident slots
    pred_req = TransitionPredictor(trace.request_transitions)
    pred_batch = TransitionPredictor(trace.transitions)
    assert pred_req.follow(["a"]) == ["b"]
    assert set(pred_batch.follow(["a"])) == {"b", "y"}

    # retiring a request drops its chain: the slot's next occupant never
    # links to the finished request's last step
    trace.record_request(1, ["a"])
    trace.end_request(1)
    trace.record_request(1, ["q"])  # rid reuse after retirement
    assert "q" not in trace.request_transitions.get("a", {})


def test_observe_without_predictor_is_noop(tmp_path):
    tp, _, units, _ = _mini(tmp_path)
    pf = Prefetcher(tp, batch_units=4)
    try:
        assert pf.observe([units[0].key]) == 0
        assert pf.stats.observed == 0
    finally:
        pf.stop()
    assert not tp.is_resident(units[0].key)
