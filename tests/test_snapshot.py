"""Warm server snapshot/restore (DESIGN.md §15.3).

Contract under test:
  * capture → restore reproduces the donor's residency set, LRU order
    (via stamps), and clock on a fresh loader, moving exactly the
    resident units' bytes through the normal preload path;
  * save → load → capture round-trips byte-identically (deterministic
    plain JSON);
  * the artifact compatibility rule: a fingerprint mismatch raises under
    strict restore and degrades to a cold join under strict=False;
  * a tighter restore budget keeps the donor's hottest (newest-stamp)
    suffix — eviction order on the restored replica matches the donor;
  * multi-tenancy: restoring a warmed tenant registered with a
    HostArbiter re-charges the arbiter exactly (audit passes);
  * the predictor table round-trips through the snapshot and arms the
    restored prefetcher;
  * FleetController.register uses an offered server snapshot as the
    bootstrap fast path.
"""

import json

import numpy as np
import pytest

from repro.core import HostArbiter, snapshot as snapmod
from repro.core.on_demand import AccessTrace
from repro.core.prefetch import Prefetcher, TransitionPredictor

from test_prefetch import N_UNITS, ROWS, UNIT_BYTES, _leaf_rows, _mini


def test_capture_restore_roundtrip(tmp_path):
    donor, data, units = _mini(tmp_path, name="donor")
    # warm in a known order: unit 3 oldest, then 1, then 5 (three ensure
    # batches → three distinct stamps)
    for i in (3, 1, 5):
        donor.ensure([units[i].key])
    snap = snapmod.capture(donor)
    assert snap["version"] == snapmod.SNAPSHOT_VERSION
    assert [k for k, _ in snap["resident"]] == [units[i].key for i in (3, 1, 5)]

    fresh, _, _ = _mini(tmp_path, name="fresh")
    report = snapmod.restore(fresh, snap)
    assert report["restored"] == 3 and report["skipped_foreign"] == 0
    assert report["moved_bytes"] == 3 * UNIT_BYTES
    assert fresh.resident_keys == donor.resident_keys
    # stamps (and therefore eviction order) reproduced exactly
    assert {k: fresh.residency._stamp[k] for k in fresh.resident_keys} == \
           {k: donor.residency._stamp[k] for k in donor.resident_keys}
    assert fresh.residency._clock >= donor.residency._clock
    # bytes are the real unit content, not placeholders
    for i in (3, 1, 5):
        np.testing.assert_array_equal(
            _leaf_rows(fresh, units[i]), data[units[i].rows[0]:units[i].rows[1]])
    # a second restore is idempotent (everything already resident)
    report2 = snapmod.restore(fresh, snap)
    assert report2["moved_bytes"] == 0 and report2["restored"] == 3


def test_snapshot_json_roundtrip_byte_identical(tmp_path):
    donor, _, units = _mini(tmp_path, name="json")
    for i in (0, 4, 2):
        donor.ensure([units[i].key])
    snap = snapmod.capture(donor)
    p = str(tmp_path / "snap.json")
    snapmod.save(snap, p)
    loaded = snapmod.load(p)
    assert json.dumps(loaded, sort_keys=True) == json.dumps(snap, sort_keys=True)
    # restore from the loaded document behaves identically
    fresh, _, _ = _mini(tmp_path, name="json2")
    assert snapmod.restore(fresh, loaded)["restored"] == 3


def test_fingerprint_compatibility_rule(tmp_path):
    art_a = tmp_path / "art-a"
    art_b = tmp_path / "art-b"
    for d, payload in ((art_a, b"aa"), (art_b, b"bbbb")):
        d.mkdir()
        (d / "optional.blob").write_bytes(payload)
    donor, _, units = _mini(tmp_path, name="fp")
    donor.ensure([units[0].key])
    snap = snapmod.capture(donor, artifact_dir=str(art_a))
    assert snap["artifact"]["fingerprint"] == snapmod.artifact_fingerprint(str(art_a))

    fresh, _, _ = _mini(tmp_path, name="fp2")
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        snapmod.restore(fresh, snap, artifact_dir=str(art_b))
    # non-strict: cold join, nothing restored, report says why
    rep = snapmod.restore(fresh, snap, artifact_dir=str(art_b), strict=False)
    assert rep["fingerprint_ok"] is False and rep["restored"] == 0
    assert fresh.resident_keys == set()
    # matching artifact restores fine either way
    rep = snapmod.restore(fresh, snap, artifact_dir=str(art_a))
    assert rep["fingerprint_ok"] is True and rep["restored"] == 1
    # version gate
    with pytest.raises(ValueError, match="snapshot version"):
        snapmod.restore(fresh, {"version": 99})


def test_restore_under_tighter_budget_keeps_hottest_suffix(tmp_path):
    donor, _, units = _mini(tmp_path, name="big")
    order = [5, 0, 2, 7, 4, 1]
    for i in order:
        donor.ensure([units[i].key])
    snap = snapmod.capture(donor)
    tight, _, _ = _mini(tmp_path, budget=3 * UNIT_BYTES, name="tight")
    rep = snapmod.restore(tight, snap)
    # oldest-first replay → LRU eviction sheds the donor's coldest units
    assert rep["restored"] == 3
    assert tight.resident_keys == {units[i].key for i in order[-3:]}


def test_restore_skips_foreign_units(tmp_path):
    donor, _, units = _mini(tmp_path, name="donorf")
    donor.ensure([units[0].key])
    snap = snapmod.capture(donor)
    snap["resident"].insert(0, ["not-a-real-unit", 0])
    snap["requested"] = len(snap["resident"])
    fresh, _, _ = _mini(tmp_path, name="freshf")
    rep = snapmod.restore(fresh, snap)
    assert rep["skipped_foreign"] == 1 and rep["restored"] == 1


def test_predictor_table_roundtrips_and_arms_prefetcher(tmp_path):
    trace = AccessTrace()
    trace.record(["a"], ["a"])
    trace.record(["b"], ["b"])
    trace.record(["c"], ["c"], phase="decode")
    pred = TransitionPredictor.from_trace(trace)
    clone = TransitionPredictor.from_dict(pred.to_dict())
    assert clone.to_dict() == pred.to_dict()
    assert clone.follow(["a"], phase="", prev=[]) == pred.follow(["a"], phase="", prev=[])

    donor, _, units = _mini(tmp_path, name="pd")
    donor.ensure([units[0].key])
    pf_donor = Prefetcher(donor, predictor=pred)
    try:
        snap = snapmod.capture(donor, prefetcher=pf_donor)
    finally:
        pf_donor.stop()
    assert snap["predictor"] == pred.to_dict()

    fresh, _, _ = _mini(tmp_path, name="pd2")
    pf_fresh = Prefetcher(fresh)
    try:
        rep = snapmod.restore(fresh, snap, prefetcher=pf_fresh)
        assert rep["predictor_armed"]
        assert pf_fresh.predictor is not None
        assert pf_fresh.predictor.to_dict() == pred.to_dict()
    finally:
        pf_fresh.stop()


def test_multitenant_restore_recharges_arbiter_exactly(tmp_path):
    """ISSUE satellite: round-trip a warmed server registered with a
    HostArbiter — restored residency bytes are re-charged to the arbiter
    exactly, and ``audit()`` passes."""
    donor, _, units = _mini(tmp_path, name="mt-donor")
    arb_a = HostArbiter(N_UNITS * UNIT_BYTES * 2)
    arb_a.register("donor", donor, share=1.0)
    for i in (2, 6, 1, 4):
        donor.ensure([units[i].key])
    arb_a.audit()
    snap = snapmod.capture(donor)

    # a fresh host: the restored tenant shares the pool with a co-tenant
    fresh, _, _ = _mini(tmp_path, name="mt-fresh")
    other, _, o_units = _mini(tmp_path, name="mt-other")
    arb_b = HostArbiter(N_UNITS * UNIT_BYTES * 2)
    arb_b.register("restored", fresh, share=0.5)
    arb_b.register("other", other, share=0.5)
    other.ensure([o_units[0].key])

    rep = snapmod.restore(fresh, snap)
    assert rep["restored"] == 4 and rep["moved_bytes"] == 4 * UNIT_BYTES
    audit = arb_b.audit()  # raises on any charge/resident inconsistency
    per = audit["tenants"]["restored"]
    # every restored byte went through make_room → charged exactly once
    assert per["resident_bytes"] == 4 * UNIT_BYTES
    assert fresh.residency.charged_bytes() == 4 * UNIT_BYTES
    assert audit["resident_bytes"] == 5 * UNIT_BYTES  # + the co-tenant's unit
    # donor and restored replica agree on the resident set and LRU stamps
    assert fresh.resident_keys == donor.resident_keys


def test_fleet_register_bootstraps_from_server_snapshot(tmp_path):
    """The §15.3 fast path in FleetController.register: an offered server
    snapshot restores a joining replica before any overlay machinery."""
    from types import SimpleNamespace

    from repro.core import FleetController

    donor, _, units = _mini(tmp_path, name="fl-donor")
    for i in (0, 3):
        donor.ensure([units[i].key])
    snap = snapmod.capture(donor)

    fleet = FleetController()
    with pytest.raises(ValueError, match="snapshot version"):
        fleet.offer_server_snapshot({"version": 99})
    fleet.offer_server_snapshot(snap)

    joiner, _, _ = _mini(tmp_path, name="fl-joiner")
    daemon = SimpleNamespace(  # register() only touches these daemon attrs
        tiered=joiner, reach=None, prefetcher=None, artifact_dir=None)
    warmed = fleet.register("replica-0", daemon)
    assert warmed
    assert joiner.resident_keys == donor.resident_keys
    assert fleet.stats.bootstraps == 1 and fleet.stats.bootstrap_failures == 0
    # the snapshot rides the fleet's own snapshot/restore round-trip
    fc2 = FleetController.restore(fleet.snapshot())
    joiner2, _, _ = _mini(tmp_path, name="fl-joiner2")
    daemon2 = SimpleNamespace(tiered=joiner2, reach=None, prefetcher=None,
                              artifact_dir=None)
    assert fc2.register("replica-1", daemon2)
    assert joiner2.resident_keys == donor.resident_keys
