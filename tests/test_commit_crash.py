"""Crash safety of the rename-commit rule (DESIGN.md §6).

Every artifact writer in this repo stages into a ``*.partial`` directory
and publishes via ``checkpoint.manager.commit_dir``. These tests simulate
a crash in the window the rule is supposed to protect — after staging is
complete, before the rename — and assert the contract:

  * the original (committed) artifact is untouched, byte for byte;
  * the orphaned staging directory is detectable (``orphaned_partials``)
    and cleanable (``clean_partials``) without risk to committed data;
  * recovery is "just re-run the rewrite": a retried commit from a fresh
    staging pass succeeds and the orphan never resurrects.

Covered writers: ``commit_dir`` itself, ``CheckpointManager.save`` (the
manifest stays on the previous step), and the re-tiering artifact rewrite
``retier_artifact`` — the code path behind the online daemon's periodic
``-compact`` rewrite (``RetierDaemon.compact``), where a mid-compaction
crash must leave the artifact the server is reading from intact.
"""

import json
import os
import types

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    clean_partials,
    commit_dir,
    orphaned_partials,
)
from repro.checkpoint import tensorstore_lite as tsl
from repro.core import (
    AccessTrace,
    OptionalStore,
    build_artifact,
    replan_from_trace,
    retier_artifact,
)
from repro.core.entrypoints import SERVING_PROFILE
from repro.core.param_graph import ReachabilityReport
from repro.core.partition import TierDecision, TierPlan, Unit


def _write_tree(d, files):
    os.makedirs(d, exist_ok=True)
    for name, content in files.items():
        with open(os.path.join(d, name), "w") as f:
            f.write(content)


def _read_tree(d):
    out = {}
    for name in sorted(os.listdir(d)):
        with open(os.path.join(d, name)) as f:
            out[name] = f.read()
    return out


# ---------------------------------------------------------------------------
# commit_dir: the primitive
# ---------------------------------------------------------------------------

def test_crash_after_staging_leaves_original_untouched(tmp_path):
    """Staging completed, rename never happened (crash between the two):
    the committed artifact is byte-identical, the orphan is detectable and
    cleanable, and cleanup cannot touch committed data."""
    final = str(tmp_path / "artifact")
    _write_tree(final, {"data.bin": "v1", "meta.json": '{"v": 1}'})
    before = _read_tree(final)

    tmp = final + ".partial"
    _write_tree(tmp, {"data.bin": "v2", "meta.json": '{"v": 2}'})
    # -- crash here: commit_dir(tmp, final) is never reached -----------------

    assert _read_tree(final) == before
    assert orphaned_partials(str(tmp_path)) == [tmp]
    assert clean_partials(str(tmp_path)) == [tmp]
    assert not os.path.exists(tmp)
    assert _read_tree(final) == before          # cleanup touched only the orphan
    assert orphaned_partials(str(tmp_path)) == []

    # recovery = re-run the rewrite: a fresh staging pass commits cleanly
    _write_tree(tmp, {"data.bin": "v2", "meta.json": '{"v": 2}'})
    commit_dir(tmp, final)
    assert _read_tree(final)["data.bin"] == "v2"
    assert not os.path.exists(tmp)


def test_orphan_scan_ignores_committed_dirs_and_files(tmp_path):
    _write_tree(str(tmp_path / "artifact"), {"a": "1"})
    _write_tree(str(tmp_path / "other.partial"), {"b": "2"})
    # a stray *file* with the suffix is not a staging dir
    with open(str(tmp_path / "trace.json.partial"), "w") as f:
        f.write("{}")
    assert orphaned_partials(str(tmp_path)) == [str(tmp_path / "other.partial")]
    assert orphaned_partials(str(tmp_path / "missing")) == []


# ---------------------------------------------------------------------------
# CheckpointManager: manifest stays on the previous step
# ---------------------------------------------------------------------------

def test_checkpoint_crash_between_staging_and_rename(tmp_path, monkeypatch):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, async_save=False)
    tree = {"w": np.arange(8, dtype=np.float32)}
    mgr.save(100, {"params": tree})
    assert mgr.latest_step() == 100

    def crash(tmp, final):
        raise OSError("simulated crash between staging and rename")

    monkeypatch.setattr("repro.checkpoint.manager.commit_dir", crash)
    with pytest.raises(OSError, match="simulated crash"):
        mgr.save(200, {"params": {"w": np.arange(8, dtype=np.float32) * 2}})

    # the previous commit is fully intact: manifest, directory, bytes
    assert mgr.latest_step() == 100
    assert mgr.all_steps() == [100]
    restored = mgr.restore(abstract={"params": tree})
    assert restored.step == 100
    np.testing.assert_array_equal(restored.collections["params"]["w"], tree["w"])
    # the torn step is absent; its staging dir is the detectable orphan
    assert not os.path.exists(os.path.join(d, "step_00000200"))
    orphans = orphaned_partials(d)
    assert orphans == [os.path.join(d, "step_00000200.partial")]
    clean_partials(d)
    assert orphaned_partials(d) == []
    assert mgr.restore().step == 100            # cleanup didn't touch step 100


# ---------------------------------------------------------------------------
# retier_artifact: the daemon's -compact rewrite path
# ---------------------------------------------------------------------------

def _mini_artifact(tmp_path):
    """A tiny real two-tier artifact + a replanned plan (the shapes
    retier_artifact moves bytes between), as in tests/test_retier.py."""
    rng = np.random.default_rng(1)
    params = {
        "a": rng.standard_normal((8, 8)).astype(np.float32),
        "emb": rng.standard_normal((64, 4)).astype(np.float32),
    }
    row_units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * 16, (g + 1) * 16), nbytes=16 * 4 * 4)
        for g in range(4)
    )
    decisions = {
        "a": TierDecision("a", 0, "leaf", "dense", params["a"].nbytes),
        "emb": TierDecision("emb", 1, "rows", "rows", params["emb"].nbytes,
                            units=row_units, resident_units=(row_units[0].key,)),
    }
    plan = TierPlan(decisions, SERVING_PROFILE, ["prefill"])
    reach = ReachabilityReport(entry_names=["prefill"],
                               reachable={"a": {"prefill"}, "emb": {"prefill"}})
    result = types.SimpleNamespace(plan=plan, reach=reach, profile=SERVING_PROFILE)
    outdir = str(tmp_path / "artifact")
    build_artifact(params, result, outdir)

    trace = AccessTrace()
    trace.record(["emb#rg2", "emb#rg3"], ["emb#rg2", "emb#rg3"], "prefill")
    new_plan, _ = replan_from_trace(plan, trace, reach)
    return outdir, new_plan, params, row_units


def test_compact_crash_preserves_source_artifact(tmp_path, monkeypatch):
    """A crash at the commit point of the artifact rewrite (the daemon's
    periodic ``-compact``) must leave the artifact the running server
    reads from untouched, with only a detectable orphan behind."""
    outdir, new_plan, params, row_units = _mini_artifact(tmp_path)
    src_files = {
        n: open(os.path.join(outdir, n), "rb").read()
        for n in sorted(os.listdir(outdir))
        if os.path.isfile(os.path.join(outdir, n))
    }
    compact_dir = outdir + "-compact"  # the daemon's default out_dir naming

    def crash(tmp, final):
        raise OSError("simulated crash between staging and rename")

    monkeypatch.setattr("repro.core.retier.commit_dir", crash)
    with pytest.raises(OSError, match="simulated crash"):
        retier_artifact(outdir, new_plan, out_dir=compact_dir)

    # source artifact byte-identical; rewrite never became visible
    for n, blob in src_files.items():
        assert open(os.path.join(outdir, n), "rb").read() == blob, n
    assert not os.path.exists(compact_dir)
    orphans = orphaned_partials(str(tmp_path))
    assert orphans == [compact_dir + ".partial"]
    clean_partials(str(tmp_path))

    # recovery: re-run the rewrite with the crash gone — commits cleanly
    monkeypatch.setattr("repro.core.retier.commit_dir", commit_dir)
    retier_artifact(outdir, new_plan, out_dir=compact_dir)
    assert os.path.exists(os.path.join(compact_dir, "artifact.json"))
    assert not os.path.exists(compact_dir + ".partial")
    store = OptionalStore(os.path.join(compact_dir, "optional.blob"))
    for u in row_units:
        np.testing.assert_array_equal(
            store.fetch(u.key), params["emb"][u.rows[0]: u.rows[1]])
    store.close()
    with open(os.path.join(compact_dir, "artifact.json")) as f:
        assert json.load(f)["decisions"]["emb"]["resident_units"] == [
            "emb#rg2", "emb#rg3"]


# ---------------------------------------------------------------------------
# OptionalStoreWriter: the blob-then-manifest commit ordering inside one store
# ---------------------------------------------------------------------------

def test_store_crash_between_blob_and_manifest_renames_is_detected(tmp_path):
    """``OptionalStoreWriter.close()`` has TWO commit points: the blob
    rename, then the manifest rename. A crash between them leaves a new
    blob beside the previous build's manifest — undetectable by mtime,
    catastrophic if served (every offset points into the wrong bytes).
    The v2 manifest records the committed blob length, so the skew is a
    typed ``StoreSkewError`` at open (DESIGN.md §17.4), and recovery is
    re-running the build."""
    from repro.core.optional_store import StoreSkewError, write_store

    rng = np.random.default_rng(2)
    units_v1 = [(f"u{i}", rng.standard_normal((16, 8)).astype(np.float32))
                for i in range(4)]
    units_v2 = [(f"u{i}", rng.standard_normal((16, 8)).astype(np.float32))
                for i in range(6)]
    path = str(tmp_path / "optional.blob")
    write_store(path, units_v1)
    with open(path + ".manifest.json", "rb") as f:
        manifest_v1 = f.read()

    # build v2, then simulate the crash: its blob rename landed (write the
    # new blob over the old), but the manifest rename never happened
    path2 = str(tmp_path / "v2.blob")
    write_store(path2, units_v2)
    os.replace(path2, path)                       # commit point 1 of build 2
    with open(path + ".manifest.json", "wb") as f:
        f.write(manifest_v1)                      # commit point 2: never ran

    with pytest.raises(StoreSkewError, match="different builds"):
        OptionalStore(path)

    # recovery = re-run the build: both renames land, the store opens and
    # round-trips the v2 bytes
    write_store(path, units_v2)
    store = OptionalStore(path)
    try:
        for k, arr in units_v2:
            np.testing.assert_array_equal(store.fetch(k), arr)
    finally:
        store.close()
