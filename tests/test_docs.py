"""Documentation contract: every ``DESIGN.md §X`` reference in the source
tree resolves to a real section heading, and the README's commands point
at files that exist."""

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REF_RE = re.compile(r"DESIGN\.md\s*\n?\s*§([\w][\w.\-]*)")


def _py_files():
    for root in ("src", "benchmarks", "examples"):
        for dirpath, _, names in os.walk(os.path.join(REPO, root)):
            for n in names:
                if n.endswith(".py"):
                    yield os.path.join(dirpath, n)


def test_design_md_exists():
    assert os.path.exists(os.path.join(REPO, "DESIGN.md"))
    assert os.path.exists(os.path.join(REPO, "README.md"))


def test_every_design_section_reference_resolves():
    with open(os.path.join(REPO, "DESIGN.md")) as f:
        design = f.read()
    headings = set()
    for line in design.splitlines():
        if line.startswith("#"):
            headings.update(re.findall(r"§([\w][\w.\-]*)", line))
    # "§4.1" also satisfies a bare "§4" style prefix check; require exact
    missing = {}
    for path in _py_files():
        with open(path) as f:
            src = f.read()
        for tok in REF_RE.findall(src):
            tok = tok.rstrip(".")
            if tok not in headings:
                missing.setdefault(tok, []).append(os.path.relpath(path, REPO))
    assert not missing, f"unresolved DESIGN.md section references: {missing}"


def test_design_covers_phase_mapping_and_residency_policies():
    """The sections the cold_start/partition docstrings lean on exist and
    say what those docstrings claim they say."""
    with open(os.path.join(REPO, "DESIGN.md")) as f:
        design = f.read()
    # §2: the read/upload/compile phase mapping
    s2 = design.split("## §2")[1].split("## §3")[0]
    for phase in ("read", "upload", "compile"):
        assert phase in s2
    # §4.2: the strict|stats|full residency policies as budget presets
    s42 = design.split("### §4.2")[1].split("## §5")[0]
    for policy in ("strict", "stats", "full"):
        assert policy in s42
    # §8: the state machine and its invariants
    s8 = design[design.index("## §8 —"):]
    for word in ("COLD", "LOADING", "RESIDENT", "pin", "evict"):
        assert word in s8


def test_design_hardware_adaptation_note_exists():
    with open(os.path.join(REPO, "DESIGN.md")) as f:
        design = f.read()
    assert "Hardware-adaptation note" in design or "hardware-adaptation note" in design


def test_readme_referenced_paths_exist():
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for rel in re.findall(r"(?:examples|benchmarks)/[\w./]+\.py", readme):
        assert os.path.exists(os.path.join(REPO, rel)), rel
    assert "PYTHONPATH=src python -m pytest" in readme  # the tier-1 command
