"""Online re-tiering daemon (DESIGN.md §12).

Covers the daemon's acceptance contract:
  * live apply — demand-faulted units join the hot set and preload
    (synchronously without a prefetcher, through the prefetch queue with
    one), decayed-out residents are demoted and evicted, and the plan on
    the running ``TieredParams`` is replaced in place;
  * cadence — step-count and wall-clock triggers, empty-window skips;
  * decay — a phase the traffic shifted away from is forgotten window by
    window and its hot-set entries demoted;
  * safety under concurrency (threaded stress) — the daemon applying
    promote/demote plans while request threads hammer
    ``ensure(pin=True)`` never evicts a pinned unit, never corrupts a
    pinned unit's bytes, and leaves budget/bookkeeping exact;
  * end-to-end — scheduler-served greedy outputs are IDENTICAL with the
    daemon on vs off, the scheduler's per-request trace tagging feeds it,
    and periodic compaction publishes the adapted artifact out-of-place.
"""

import threading
import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (
    AccessTrace,
    DeploymentProfile,
    OptionalStore,
    Prefetcher,
    RetierDaemon,
    TieredParams,
    analyze,
    build_artifact,
)
from repro.core.entrypoints import SERVING_PROFILE
from repro.core.optional_store import write_store
from repro.core.param_graph import ReachabilityReport
from repro.core.partition import TierDecision, TierPlan, Unit
from repro.models.zoo import build_model
from repro.serving import ContinuousBatchingScheduler, GenerationEngine, cold_start

ROWS, COLS, N_UNITS = 16, 32, 8
UNIT_BYTES = ROWS * COLS * 4


def _mini(tmp_path, budget=None, name="mini", resident=()):
    """One row-tiered leaf over a real optional store + the static reach
    report the daemon's invariant check needs (no model)."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal((N_UNITS * ROWS, COLS)).astype(np.float32)
    units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * ROWS, (g + 1) * ROWS), nbytes=UNIT_BYTES)
        for g in range(N_UNITS)
    )
    dec = TierDecision("emb", 1, "rows", "test", data.nbytes, units=units,
                       resident_units=tuple(resident))
    plan = TierPlan({"emb": dec}, SERVING_PROFILE, [])
    path = str(tmp_path / f"{name}.blob")
    write_store(path, [(u.key, data[u.rows[0]: u.rows[1]]) for u in units])
    tp = TieredParams(
        {"emb": jnp.zeros(data.shape, jnp.float32)}, plan, OptionalStore(path),
        device_budget_bytes=budget,
    )
    reach = ReachabilityReport(entry_names=["prefill", "decode_step"],
                               reachable={"emb": {"prefill"}})
    return tp, data, units, reach


def _rows_of(tp, unit):
    lo, hi = unit.rows
    return np.asarray(tp.leaf("emb"))[lo:hi]


# ---------------------------------------------------------------------------
# live apply: promote / demote / plan swap
# ---------------------------------------------------------------------------

def test_daemon_applies_promotions_and_demotions_live(tmp_path):
    tp, data, units, reach = _mini(tmp_path)
    keys = [u.key for u in units]
    # hand-install a hot set: rg0 and rg1 "preloaded at cold start"
    tp.plan.decisions["emb"] = TierDecision(
        "emb", 1, "rows", "test", tp.plan.decisions["emb"].nbytes,
        units=units, resident_units=(keys[0], keys[1]),
    )
    tp.ensure([keys[0], keys[1]], source="preload")
    daemon = RetierDaemon(tp, reach, interval_steps=1)
    assert tp.trace is not None  # the daemon attached its live trace

    tp.ensure([keys[0]])           # touch one preload, never the other
    tp.ensure([keys[4], keys[5]])  # two demand faults

    rep = daemon.maybe_tick()
    assert rep is not None
    res = tp.plan.decisions["emb"].resident_units  # plan swapped in place
    assert keys[4] in res and keys[5] in res       # faulted → promoted
    assert keys[0] in res and keys[1] not in res   # untouched → demoted
    # the demotion was a real eviction back to placeholder zeros...
    assert not tp.is_resident(keys[1])
    np.testing.assert_array_equal(_rows_of(tp, units[1]), np.zeros((ROWS, COLS), np.float32))
    # ...while the promoted units are resident with exact bytes (the sync
    # no-prefetcher preload path — here they were already warm from the fault)
    for g in (4, 5):
        assert tp.is_resident(keys[g])
        np.testing.assert_array_equal(_rows_of(tp, units[g]), data[g * ROWS:(g + 1) * ROWS])
    s = daemon.stats
    assert s.ticks == s.applies == s.invariant_checks == 1
    assert s.promoted_units == 2 and s.demoted_units == 1
    assert s.evicted_units == 1 and s.evicted_bytes == UNIT_BYTES


def test_daemon_preloads_through_prefetcher_and_refreshes_predictor(tmp_path):
    tp, data, units, reach = _mini(tmp_path)
    keys = [u.key for u in units]
    pf = Prefetcher(tp, batch_units=4)
    daemon = RetierDaemon(tp, reach, prefetcher=pf, interval_steps=1)
    try:
        # a request chain faults rg2 then rg3, which then get evicted
        tp.ensure([keys[2]])
        tp.ensure([keys[3]])
        tp.evict([keys[2], keys[3]])
        assert not tp.is_resident(keys[2]) and not tp.is_resident(keys[3])

        rep = daemon.tick()
        assert rep is not None and set(rep.promoted_resident) == {keys[2], keys[3]}
        # promotions rode the prefetch queue, not the request path
        assert pf.drain(10.0)
        for g in (2, 3):
            assert tp.is_resident(keys[g])
            np.testing.assert_array_equal(_rows_of(tp, units[g]), data[g * ROWS:(g + 1) * ROWS])
        reloads = [e for e in tp.stats.events if e.key in (keys[2], keys[3])
                   and e.source == "prefetch"]
        assert len(reloads) == 2
        # the predictor was retrained from the merged trace's transitions
        assert daemon.stats.predictor_refreshes == 1
        assert pf.predictor is not None
        assert keys[3] in pf.predictor.successors(keys[2])
    finally:
        pf.stop()


def test_daemon_decay_forgets_shifted_away_phase(tmp_path):
    """Workload shift: units hot in an old window decay out of the merged
    trace and get demoted + evicted — the hot set tracks the traffic."""
    tp, data, units, reach = _mini(tmp_path)
    keys = [u.key for u in units]
    daemon = RetierDaemon(tp, reach, interval_steps=1, decay=0.5)

    tp.ensure([keys[2]])  # phase A
    assert daemon.tick() is not None
    assert keys[2] in tp.plan.decisions["emb"].resident_units

    for _ in range(3):    # phase B windows: rg2 never touched again
        tp.ensure([keys[6]])
        daemon.tick()
    # 1 → 0.5 → pruned: rg2 left the merged profile, so it was demoted
    assert keys[2] not in tp.plan.decisions["emb"].resident_units
    assert not tp.is_resident(keys[2])
    assert keys[6] in tp.plan.decisions["emb"].resident_units
    assert daemon.stats.demoted_units >= 1


# ---------------------------------------------------------------------------
# cadence
# ---------------------------------------------------------------------------

def test_daemon_cadence_step_and_wallclock_triggers(tmp_path):
    tp, _, units, reach = _mini(tmp_path)
    daemon = RetierDaemon(tp, reach, interval_steps=3)
    tp.ensure([units[0].key])
    assert daemon.maybe_tick() is None      # 1
    assert daemon.maybe_tick() is None      # 2
    assert daemon.maybe_tick() is not None  # 3: due
    assert daemon.stats.ticks == 1

    # empty windows are skipped (counted, nothing applied)
    assert daemon.maybe_tick(steps=3) is None
    assert daemon.stats.skipped_empty == 1
    assert daemon.stats.applies == 1

    # wall-clock trigger fires even with zero new steps
    wall = RetierDaemon(tp, reach, interval_steps=10**9, interval_s=0.05)
    tp.ensure([units[1].key])
    assert wall.maybe_tick(steps=0) is None
    time.sleep(0.08)
    assert wall.maybe_tick(steps=0) is not None

    with pytest.raises(ValueError, match="interval_steps"):
        RetierDaemon(tp, reach, interval_steps=0)
    with pytest.raises(ValueError, match="artifact_dir"):
        RetierDaemon(tp, reach, compact_every=2)
    # bad decay fails at construction, not two ticks into serving
    with pytest.raises(ValueError, match="decay"):
        RetierDaemon(tp, reach, decay=1.5)


def test_daemon_compact_failure_absorbed_serving_survives(tmp_path):
    """Compaction is bookkeeping: a background compaction that raises
    (here: rewriting from a nonexistent artifact) must not propagate into
    the serving loop OR fail the tick that kicked it off — it is counted
    in the compaction-specific error stats, and later ticks keep working
    (DESIGN.md §17.3)."""
    tp, _, units, reach = _mini(tmp_path)
    daemon = RetierDaemon(tp, reach, interval_steps=1, compact_every=1,
                          artifact_dir=str(tmp_path / "no-such-artifact"))
    tp.ensure([units[0].key])
    # the tick succeeds: compaction failure is off-thread, not a tick error
    assert daemon.maybe_tick() is not None
    assert daemon.join_compaction(timeout=10.0)
    assert daemon.stats.compact_errors == 1 and daemon.last_compact_error
    assert daemon.stats.errors == 0
    assert daemon.stats.compactions == 0
    # the plan application itself landed despite the compaction failure...
    assert units[0].key in tp.plan.decisions["emb"].resident_units
    # ...and the daemon keeps serving future windows
    tp.ensure([units[1].key])
    daemon.compact_every = 0  # next tick has nothing left to fail on
    assert daemon.maybe_tick() is not None
    assert daemon.stats.compact_errors == 1


def test_compaction_runs_off_thread_and_never_blocks_a_tick(tmp_path, monkeypatch):
    """The §17.3 serve-path guard: a periodic compaction runs on a worker
    thread — the tick that triggers it returns while the rewrite is still
    in progress, a second cadence hit while one is in flight is counted
    and dropped (at most one in flight, never queued), and the completed
    rewrite lands in the compaction stats."""
    import repro.core.retier_daemon as rd_mod

    gate = threading.Event()       # held by the test: the "slow rewrite"
    started = threading.Event()
    calls = []

    def slow_retier(artifact_dir, plan, *, out_dir=None, report=None, trace=None):
        started.set()
        assert gate.wait(10.0)
        calls.append(out_dir)
        return {"fake": True}

    monkeypatch.setattr(rd_mod, "retier_artifact", slow_retier)
    tp, _, units, reach = _mini(tmp_path)
    daemon = RetierDaemon(tp, reach, interval_steps=1, compact_every=1,
                          artifact_dir=str(tmp_path / "mini-artifact"))

    tp.ensure([units[0].key])
    t0 = time.monotonic()
    assert daemon.maybe_tick() is not None  # returned...
    tick_wall = time.monotonic() - t0
    assert started.wait(10.0)               # ...while the rewrite still runs
    assert not gate.is_set() and daemon.stats.compactions == 0

    # cadence hit while one is in flight: dropped and counted, not queued
    tp.ensure([units[1].key])
    assert daemon.maybe_tick() is not None
    assert daemon.stats.compact_skipped_inflight == 1

    gate.set()
    assert daemon.join_compaction(timeout=10.0)
    assert daemon.stats.compactions == 1 and len(calls) == 1
    assert daemon.stats.compact_errors == 0
    assert daemon.last_compaction == {"fake": True}
    # the serve-path cost of the triggering tick excludes the rewrite wall
    assert daemon.stats.max_tick_s < 5.0 and tick_wall < 5.0
    assert daemon.stats.compact_wall_s > 0.0


def test_emit_hints_attributes_final_step_then_drops_chain(tmp_path):
    """A request's LAST step is recorded before its chain state is
    dropped: the transition into the terminal step's units is profiling
    signal, but the freed slot's next occupant must not link to it."""
    tp, _, units, _ = _mini(tmp_path)
    tp.start_trace()
    req = types.SimpleNamespace(rid=7)
    fake = types.SimpleNamespace(
        server=types.SimpleNamespace(tiered=tp),
        engine=types.SimpleNamespace(prefetcher=None),
        _slots=[req],
    )
    k = [u.key for u in units]
    ContinuousBatchingScheduler._emit_hints(fake, [], by_request={7: [k[0]]})
    fake._slots = [None]  # the request retired during this step
    ContinuousBatchingScheduler._emit_hints(fake, [], by_request={7: [k[1]]})
    # the final step WAS attributed (k0 → k1 is a real per-request chain)
    assert tp.trace.request_transitions[k[0]] == {k[1]: 1}
    # and the chain state is gone: the slot's next occupant can't link in
    assert tp.trace._last_by_request == {}


# ---------------------------------------------------------------------------
# the satellite stress: concurrent apply vs pinned request traffic
# ---------------------------------------------------------------------------

def test_daemon_stress_never_evicts_pinned_budget_holds(tmp_path):
    """Request threads run the scheduler's step pattern — ``ensure(pin=True)``
    … verify bytes … ``release()`` — under a tight budget while the daemon
    concurrently rotates traces, replans, preloads promotions, and evicts
    demotions. A pinned unit must never be evicted or zeroed mid-step, and
    the budget/bookkeeping must be exact once the dust settles."""
    budget = 4 * UNIT_BYTES
    tp, data, units, reach = _mini(tmp_path, budget=budget)
    keys = [u.key for u in units]
    daemon = RetierDaemon(tp, reach, interval_steps=1, decay=0.5)
    errors: list = []
    stop = threading.Event()

    def requester(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(40):
                step = [str(k) for k in rng.choice(keys, size=2, replace=False)]
                tp.ensure(step, pin=True)
                try:
                    # the mid-step invariant: pinned units stay RESIDENT
                    # with exact bytes no matter what the daemon applies
                    for k in step:
                        assert tp.is_resident(k), f"pinned {k} not resident"
                        u = units[keys.index(k)]
                        np.testing.assert_array_equal(
                            _rows_of(tp, u), data[u.rows[0]: u.rows[1]])
                finally:
                    tp.release(step)
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    def daemon_loop():
        try:
            while not stop.is_set():
                daemon.tick()
                time.sleep(0.002)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=requester, args=(i,)) for i in range(4)]
    dt = threading.Thread(target=daemon_loop)
    dt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    dt.join()

    assert not errors, errors
    assert daemon.stats.applies > 0          # the daemon really ran
    assert daemon.stats.invariant_checks == daemon.stats.applies
    res = tp.residency
    # all pins released and the daemon's sync preloads respect eviction
    # rules → the budget holds at rest, and bookkeeping is exact
    assert res.resident_bytes <= budget
    resident = res.resident_keys
    assert res.resident_bytes == len(resident) * UNIT_BYTES
    for u in units:
        expect = (data[u.rows[0]: u.rows[1]] if u.key in resident
                  else np.zeros((ROWS, COLS), np.float32))
        np.testing.assert_array_equal(_rows_of(tp, u), expect)


# ---------------------------------------------------------------------------
# end-to-end: scheduler + daemon, outputs identical, compaction published
# ---------------------------------------------------------------------------

ARCH = "mixtral-8x22b"
PROMPT_LEN = 6
MAX_SEQ = 16


@pytest.fixture(scope="module")
def app(tmp_path_factory):
    cfg = get_reduced(ARCH).replace(collect_moe_usage=True)
    model = build_model(cfg)
    profile = DeploymentProfile(resident_experts=1, hot_vocab_fraction=0.25,
                                min_tier1_bytes=1024, vocab_row_group=128)
    res = analyze(model, profile, trace_B=1, trace_S=16)
    params = model.init(jax.random.PRNGKey(0))
    outdir = str(tmp_path_factory.mktemp("retierd"))
    build_artifact(params, res, outdir)
    return cfg, model, res, outdir


def test_scheduler_outputs_identical_daemon_on_vs_off(app, tmp_path):
    """The acceptance gate: live re-tiering may move bytes, never tokens —
    under eviction pressure, with the daemon compacting the artifact as it
    goes and the scheduler feeding it per-request trace tags."""
    cfg, model, res, outdir = app
    prompts = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(70 + i), (PROMPT_LEN,), 0, cfg.vocab_size))
        for i in range(5)
    ]
    steps = [4, 3, 5, 4, 3]
    budget = res.plan.tier1_bytes // 2

    def serve(**cold_kw):
        with cold_start(model, outdir, res, mode="after2",
                        warm_shapes=((1, PROMPT_LEN),),
                        device_budget_bytes=budget, **cold_kw) as server:
            sched = ContinuousBatchingScheduler(
                GenerationEngine(server, max_seq=MAX_SEQ), max_batch=3)
            reqs = [sched.submit(p, n) for p, n in zip(prompts, steps)]
            sched.run()
            assert all(r.done and r.error is None for r in reqs)
            return [r.output for r in reqs], server

    outs_off, _ = serve(prefetch=True)
    outs_on, server = serve(prefetch=True, retier_online=True,
                            retier_interval=2, retier_compact_every=1)
    for got, ref in zip(outs_on, outs_off):
        np.testing.assert_array_equal(got, ref)

    daemon = server.retier_daemon
    assert daemon is not None and daemon.stats.applies > 0
    assert daemon.stats.invariant_checks == daemon.stats.applies
    # scheduler-aware profiling reached the daemon's merged history
    merged = daemon.merged_trace
    assert merged is not None and merged.request_transitions
    # periodic compaction published the adapted artifact next to the
    # original, rename-committed (no .partial left behind)
    import json as _json
    import os
    compact = outdir.rstrip("/") + "-compact"
    assert os.path.isdir(compact)
    assert not os.path.exists(compact + ".partial")
    with open(os.path.join(compact, "artifact.json")) as f:
        art = _json.load(f)
    live = daemon.tiered.plan
    for path, d in art["decisions"].items():
        assert d["tier"] == live.decisions[path].tier
    # a compaction-published hot set boots the next cold start directly
    some = [p for p, d in art["decisions"].items() if d["resident_units"]]
    assert some, "compacted artifact lost the adapted hot set"
