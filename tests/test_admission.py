"""SLO-aware admission (DESIGN.md §15.2).

Policy-level contract without a model:
  * FIFO select pops arrival order, retires invalid requests with the
    canonical rejection message, never sheds;
  * SLO sheds hopeless requests at admission (before any prefill/decode
    is spent), re-orders the backlog by priority/deadline under burst,
    degenerates to FIFO with no deadlines, and reports its backlog via
    ``pending()``.

Scheduler integration over the real two-tier runtime:
  * default FIFO path is byte-identical to an explicit FIFOAdmission;
  * an SLOAdmission burst sheds some requests with ``error="shed: ..."``,
    serves the rest to completion with outputs equal to their solo
    sequential runs, and the loop's ``run()`` drains the policy backlog
    (the ``idle`` contract).
"""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import DeploymentProfile, analyze, build_artifact
from repro.models.zoo import build_model
from repro.serving import (
    ContinuousBatchingScheduler,
    FIFOAdmission,
    GenerationEngine,
    RequestQueue,
    SLOAdmission,
    cold_start,
)

ARCH = "mixtral-8x22b"
PROMPT_LEN = 6
MAX_SEQ = 16


def _validate_max8(req):
    S = int(req.tokens.size)
    if S == 0 or S + req.n_steps > 8 or req.n_steps < 1:
        return f"rejected: prompt {S} + {req.n_steps} steps exceeds max_seq=8 (or is empty)"
    return None


# ---------------------------------------------------------------------------
# policy level
# ---------------------------------------------------------------------------


def test_fifo_pops_arrival_order_and_rejects():
    q = RequestQueue()
    good1 = q.submit([1, 2], 3)
    bad = q.submit([1, 2, 3], 99)  # over-length
    good2 = q.submit([3], 2)
    pol = FIFOAdmission()
    admit, drop = pol.select(q, 2, time.perf_counter(), _validate_max8)
    assert [r.rid for r in admit] == [good1.rid, good2.rid]
    assert [(r.rid, kind) for r, kind, _ in drop] == [(bad.rid, "rejected")]
    assert drop[0][2].startswith("rejected: prompt 3 + 99 steps")
    assert pol.pending() == 0
    # free=0 never pops: arrival order is preserved for the next round
    q.submit([5], 1)
    admit, drop = pol.select(q, 0, time.perf_counter(), _validate_max8)
    assert admit == [] and drop == [] and len(q) == 1


def test_slo_sheds_hopeless_before_service():
    q = RequestQueue()
    hopeless = q.submit([1, 2], 5, deadline_s=1e-6)  # already expired
    fine = q.submit([1, 2], 5)                       # no deadline: never shed
    pol = SLOAdmission(step_est_s=1e-3, prefill_est_s=1e-3)
    admit, drop = pol.select(q, 4, time.perf_counter(), _validate_max8)
    assert [r.rid for r in admit] == [fine.rid]
    (req, kind, err), = drop
    assert req.rid == hopeless.rid and kind == "shed"
    assert err.startswith("shed: ")
    assert pol.shed_total == 1


def test_slo_priority_and_deadline_reorder_under_burst():
    q = RequestQueue()
    slow = q.submit([1], 2, deadline_s=60.0, priority=0)
    urgent = q.submit([1], 2, deadline_s=1.0, priority=0)
    vip = q.submit([1], 2, priority=5)
    pol = SLOAdmission(step_est_s=1e-4, prefill_est_s=1e-4)
    admit, drop = pol.select(q, 2, time.perf_counter(), _validate_max8)
    # burst of 3 into 2 slots: priority first, then earliest deadline
    assert [r.rid for r in admit] == [vip.rid, urgent.rid]
    assert drop == []
    assert pol.pending() == 1  # `slow` waits in the policy backlog
    admit2, _ = pol.select(q, 2, time.perf_counter(), _validate_max8)
    assert [r.rid for r in admit2] == [slow.rid]
    assert pol.pending() == 0


def test_slo_no_deadline_degenerates_to_fifo():
    q = RequestQueue()
    reqs = [q.submit([1], 2) for _ in range(5)]
    pol = SLOAdmission()
    admit, drop = pol.select(q, 3, time.perf_counter(), _validate_max8)
    assert [r.rid for r in admit] == [r.rid for r in reqs[:3]]
    admit2, _ = pol.select(q, 3, time.perf_counter(), _validate_max8)
    assert [r.rid for r in admit2] == [r.rid for r in reqs[3:]]
    assert drop == [] and pol.shed_total == 0


def test_slo_backlogged_request_shed_when_it_becomes_hopeless():
    q = RequestQueue()
    first = q.submit([1], 2, priority=1)  # wins the single slot this round
    late = q.submit([1], 2, deadline_s=0.05)
    pol = SLOAdmission(step_est_s=1e-4, prefill_est_s=1e-4)
    admit, drop = pol.select(q, 1, time.perf_counter(), _validate_max8)
    assert [r.rid for r in admit] == [first.rid]
    assert drop == [] and pol.pending() == 1
    time.sleep(0.06)  # the backlogged deadline expires while queued
    admit2, drop2 = pol.select(q, 1, time.perf_counter(), _validate_max8)
    assert admit2 == []
    assert [(r.rid, kind) for r, kind, _ in drop2] == [(late.rid, "shed")]


def test_slo_ema_tracks_observed_service_times():
    pol = SLOAdmission(step_est_s=1e-3, ema=0.5)
    for _ in range(8):
        pol.note_step(0.1, 2)
    assert pol._step_est == pytest.approx(0.1, rel=0.05)


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def app(tmp_path_factory):
    cfg = get_reduced(ARCH).replace(collect_moe_usage=True)
    model = build_model(cfg)
    profile = DeploymentProfile(resident_experts=1, hot_vocab_fraction=0.25,
                                min_tier1_bytes=1024, vocab_row_group=128)
    res = analyze(model, profile, trace_B=1, trace_S=16)
    params = model.init(jax.random.PRNGKey(0))
    outdir = str(tmp_path_factory.mktemp("admission"))
    build_artifact(params, res, outdir)
    return cfg, model, res, outdir


def _prompts(cfg, n, seed0=0):
    return [
        np.asarray(jax.random.randint(
            jax.random.PRNGKey(seed0 + i), (PROMPT_LEN,), 0, cfg.vocab_size))
        for i in range(n)
    ]


def test_default_fifo_matches_explicit_fifo(app):
    """The refactor's parity contract: constructing the scheduler with no
    policy (the pre-refactor call sites) admits/serves identically to an
    explicit FIFOAdmission."""
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 4)
    outs = {}
    for label, admission in (("default", None), ("explicit", FIFOAdmission())):
        with cold_start(model, outdir, res, mode="after2",
                        warm_shapes=((1, PROMPT_LEN),)) as server:
            sched = ContinuousBatchingScheduler(
                GenerationEngine(server, max_seq=MAX_SEQ),
                max_batch=2, admission=admission)
            reqs = [sched.submit(p, 3) for p in prompts]
            sched.run()
            assert all(r.done and r.error is None for r in reqs)
            assert sched.stats.shed == 0
            outs[label] = [r.output for r in reqs]
    for a, b in zip(outs["default"], outs["explicit"]):
        np.testing.assert_array_equal(a, b)


def test_slo_burst_sheds_and_serves_rest_exactly(app):
    """A burst with an impossible deadline on some requests: those are
    shed unserved; the survivors' greedy tokens equal their solo runs,
    and run() drains the policy backlog (idle contract)."""
    cfg, model, res, outdir = app
    prompts = _prompts(cfg, 4, seed0=50)
    refs = []
    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),)) as server:
        eng = GenerationEngine(server, max_seq=MAX_SEQ)
        import jax.numpy as jnp
        for p in prompts:
            out, _ = eng.generate(jnp.asarray(p[None, :]), 3)
            refs.append(np.asarray(out[0]))

    with cold_start(model, outdir, res, mode="after2",
                    warm_shapes=((1, PROMPT_LEN),),
                    admission=SLOAdmission(step_est_s=5e-3)) as server:
        sched = ContinuousBatchingScheduler(
            GenerationEngine(server, max_seq=MAX_SEQ), max_batch=2)
        assert isinstance(sched.admission, SLOAdmission)  # server default wins
        good = [sched.submit(p, 3) for p in prompts[:2]]
        doomed = [sched.queue.submit(p, 3, deadline_s=1e-6) for p in prompts[2:]]
        sched.run()
        assert sched.idle  # queue, slots, AND policy backlog drained
    for r, ref in zip(good, refs[:2]):
        assert r.done and r.error is None
        np.testing.assert_array_equal(r.output, ref)
    for r in doomed:
        assert r.done and r.shed and r.error.startswith("shed: ")
        assert r.out == []  # shed BEFORE any service, not timed out after
    assert sched.stats.shed == 2
    assert sched.stats.completed == 2
