"""AccessTrace lifecycle: window merging with decay + the versioned JSON
schema (DESIGN.md §12.2).

Property-style (seeded-random, deterministic) coverage of the merge
contract the online re-tiering daemon depends on:
  * decay=1 ⇒ plain field-wise sum of the two windows;
  * decay=0 ⇒ exactly the newest window (history fully forgotten);
  * merge is deterministic and non-mutating;
  * counts decaying below the prune threshold genuinely leave the trace;
  * schema-version mismatch raises; v1 documents still load; unknown
    versions don't; merged (fractional-count) traces round-trip through
    the versioned JSON byte-identically;
  * the fleet-federation edges (DESIGN.md §14.1): ``merge_all`` of no
    windows is an empty trace, of any window permutation a byte-identical
    plain sum, and merging a trace into itself (aliasing) is rejected.
"""

import json

import numpy as np
import pytest

from repro.core import AccessTrace

KEYS = [f"u{i}" for i in range(12)]


def _random_trace(seed: int, *, n_batches: int = 15, with_requests: bool = False) -> AccessTrace:
    rng = np.random.default_rng(seed)
    t = AccessTrace()
    for i in range(n_batches):
        keys = list(rng.choice(KEYS, size=int(rng.integers(1, 5)), replace=False))
        cold = [k for k in keys if rng.random() < 0.5]
        t.record(keys, cold, phase=str(rng.choice(["prefill", "decode", ""])))
        if with_requests:
            rid = int(rng.integers(0, 3))
            t.record_request(rid, keys[: max(1, len(keys) // 2)])
    return t


def _sum_counts(a: dict, b: dict) -> dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0) + v
    return out


# ---------------------------------------------------------------------------
# decay semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_merge_decay_one_is_plain_sum(seed):
    old = _random_trace(seed, with_requests=True)
    new = _random_trace(seed + 100, with_requests=True)
    m = old.merge(new, decay=1.0)
    assert m.batches == old.batches + new.batches
    assert m.touches == _sum_counts(old.touches, new.touches)
    assert m.faults == _sum_counts(old.faults, new.faults)
    assert m.pairs == _sum_counts(old.pairs, new.pairs)
    assert m.request_pairs == _sum_counts(old.request_pairs, new.request_pairs)
    for k in set(old.transitions) | set(new.transitions):
        assert m.transitions[k] == _sum_counts(
            old.transitions.get(k, {}), new.transitions.get(k, {}))
    for k in set(old.phases) | set(new.phases):
        assert m.phases[k] == _sum_counts(old.phases.get(k, {}), new.phases.get(k, {}))
    # plain int sums stay ints — the canonical-number rule keeps a decay=1
    # pipeline byte-compatible with unmerged traces
    assert all(isinstance(v, int) for v in m.touches.values())


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_merge_decay_zero_is_newest_window_only(seed):
    old = _random_trace(seed, with_requests=True)
    new = _random_trace(seed + 200, with_requests=True)
    m = old.merge(new, decay=0.0)
    # the merged document IS the newest window's document
    assert m.to_dict() == new.to_dict()
    assert m.to_json() == new.to_json()


def test_merge_fractional_decay_scales_then_adds():
    old = AccessTrace()
    old.record(["a", "b"], ["a"], "prefill")
    old.record(["a"], [], "decode")  # a touched twice total
    new = AccessTrace()
    new.record(["a", "c"], ["c"], "decode")
    m = old.merge(new, decay=0.5)
    assert m.touches == {"a": 2.0, "b": 0.5, "c": 1}  # 2*0.5+1, 1*0.5, 0+1
    assert m.faults == {"a": 0.5, "c": 1}
    assert m.batches == 2  # 2*0.5 + 1, normalized back to int


def test_merge_prunes_decayed_entries():
    """A unit nobody touches again decays out of the profile entirely —
    the demotion path depends on absence, not on a lingering 1e-9."""
    old = AccessTrace()
    old.record(["stale"], ["stale"], "prefill")
    empty = AccessTrace()
    m = old
    for _ in range(3):  # 1 → 0.5 → pruned (default prune_below=0.5)
        m = m.merge(empty, decay=0.5)
    assert "stale" not in m.touches and "stale" not in m.faults
    # replan semantics: an absent key counts as untouched
    assert m.touches.get("stale", 0) == 0


def test_merge_invalid_decay_rejected():
    t = AccessTrace()
    for bad in (-0.1, 1.1):
        with pytest.raises(ValueError, match="decay"):
            t.merge(AccessTrace(), decay=bad)


# ---------------------------------------------------------------------------
# determinism + non-mutation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("decay", [0.0, 0.25, 0.5, 1.0])
def test_merge_deterministic_and_non_mutating(decay):
    old1, old2 = _random_trace(7, with_requests=True), _random_trace(7, with_requests=True)
    new1, new2 = _random_trace(8, with_requests=True), _random_trace(8, with_requests=True)
    before_old, before_new = old1.to_json(), new1.to_json()
    m1 = old1.merge(new1, decay=decay)
    m2 = old2.merge(new2, decay=decay)
    assert m1.to_json() == m2.to_json()  # same inputs → byte-identical
    assert old1.to_json() == before_old  # inputs untouched
    assert new1.to_json() == before_new
    # merged trace carries no in-flight chain state
    assert m1._last_batch == [] and m1._last_by_request == {}


# ---------------------------------------------------------------------------
# merge_all + aliasing (the fleet-federation edges, DESIGN.md §14.1)
# ---------------------------------------------------------------------------

def test_merge_all_of_nothing_is_an_empty_trace():
    """A sync cycle where every replica returned an empty window must
    produce a genuinely empty combined trace, not crash or fabricate."""
    m = AccessTrace.merge_all([])
    assert m.batches == 0
    assert m.to_dict() == AccessTrace().to_dict()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_merge_all_is_order_independent_plain_sum(seed):
    """§14.1 rule 1: the combine is commutative (undecayed sum), so the
    fleet plan cannot depend on replica polling order."""
    ws = [_random_trace(seed * 10 + i, with_requests=True) for i in range(4)]
    m = AccessTrace.merge_all(ws)
    perm = list(np.random.default_rng(seed).permutation(len(ws)))
    assert m.to_json() == AccessTrace.merge_all([ws[i] for i in perm]).to_json()
    # ... and equals the daemon's own decay=1 fold, window by window
    acc = ws[0]
    for w in ws[1:]:
        acc = acc.merge(w, decay=1.0)
    assert m.to_json() == acc.to_json()
    for w in ws:  # inputs untouched
        assert w.batches > 0


def test_merge_self_aliasing_rejected():
    """history.merge(history) would double-count every table in place;
    the guard turns the silent corruption into an immediate error."""
    t = _random_trace(5, with_requests=True)
    before = t.to_json()
    with pytest.raises(ValueError, match="itself"):
        t.merge(t)
    assert t.to_json() == before


# ---------------------------------------------------------------------------
# versioned JSON
# ---------------------------------------------------------------------------

def test_versioned_json_roundtrip_of_merged_trace(tmp_path):
    """Fractional counts from a decayed merge survive save → load → save
    byte-identically, version field included."""
    m = _random_trace(3, with_requests=True).merge(
        _random_trace(4, with_requests=True), decay=0.5)
    s = m.to_json()
    assert AccessTrace.from_json(s).to_json() == s
    p = str(tmp_path / "merged.json")
    m.save(p)
    assert AccessTrace.load(p).to_json() == s
    with open(p) as f:
        doc = json.load(f)
    assert doc["version"] == AccessTrace.VERSION
    assert "request_transitions" in doc and "request_pairs" in doc


def test_version_mismatch_raises_everywhere():
    a, b = AccessTrace(), AccessTrace()
    b.version = 99
    with pytest.raises(ValueError, match="schema"):
        a.merge(b)
    with pytest.raises(ValueError, match="version"):
        AccessTrace.from_dict({"version": 99})
    # v1 documents (pre request-attribution) still load, new fields empty
    t = AccessTrace.from_dict({"version": 1, "batches": 2,
                               "touches": {"a": 2}, "faults": {"a": 1}})
    assert t.touches == {"a": 2} and t.request_transitions == {}
