"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py forces the 512-device placeholder topology (and the
multi-device tests below spawn subprocesses to do the same)."""

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def rand_batch(key, spec, vocab):
    """Materialize a concrete batch from ShapeDtypeStruct specs."""
    out = {}
    for k, v in spec.items():
        kk = jax.random.fold_in(key, hash(k) % (2**31))
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(kk, v.shape, 0, vocab)
        else:
            out[k] = jax.random.normal(kk, v.shape, jnp.float32).astype(v.dtype)
    return out
