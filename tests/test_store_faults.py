"""Storage fault-injection tier (DESIGN.md §17.4) + the §17 layout rules.

The optional store is the one component between the disk and a served
tensor, so its failure modes must be *typed*, not probabilistic:

  * a torn/truncated frame (blob shorter than a manifest offset+csize)
    raises ``TornFrameError`` naming the unit key;
  * a corrupted zlib stream (or a decode disagreeing with the manifest's
    rsize) raises ``CorruptFrameError`` naming the unit key;
  * a blob/manifest mismatch after a crash between the writer's two
    commit renames raises ``StoreSkewError`` at OPEN, before any read;
  * a crash mid-compaction leaves only a ``.partial`` staging dir that
    ``orphaned_partials`` finds — the source artifact stays serveable.

None of these may ever return garbage bytes into a placeholder tree.

The layout half pins the §17.1-§17.2 contracts: raw-frame compaction
copies compressed frames byte-identically (zero recompressions for an
unchanged plan), co-access ordering makes traced clusters byte-adjacent,
and ``read_raw_many`` coalescing is byte-identical to per-key reads under
permuted key order, overlapping batches, and a gap threshold of 0 (one
pread per frame). A ``slow`` hypothesis property round-trips the codec
over dtype x shape x level, including bf16 byte-planing and level=0 raw.
"""

import json
import os
import zlib

import numpy as np
import pytest

from repro.checkpoint.manager import clean_partials, orphaned_partials
from repro.core.on_demand import AccessTrace
from repro.core.optional_store import (
    COALESCE_GAP,
    CorruptFrameError,
    OptionalStore,
    OptionalStoreWriter,
    ReadStats,
    StoreError,
    StoreSkewError,
    TornFrameError,
    write_store,
)
from repro.core.retier import coaccess_order, retier_artifact

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

N_UNITS, ROWS, COLS = 8, 16, 32


def _units(seed=0, n=N_UNITS):
    rng = np.random.default_rng(seed)
    return [(f"emb#rg{g}", rng.standard_normal((ROWS, COLS)).astype(np.float32))
            for g in range(n)]


def _store(tmp_path, name="s.blob", units=None, level=6):
    path = str(tmp_path / name)
    write_store(path, units if units is not None else _units(), level=level)
    return path


def _manifest(path):
    with open(path + ".manifest.json") as f:
        return json.load(f)


def _rewrite_manifest(path, doc):
    with open(path + ".manifest.json", "w") as f:
        json.dump(doc, f)


# ---------------------------------------------------------------------------
# fault injection: every failure is typed and names the unit
# ---------------------------------------------------------------------------

def test_truncated_blob_raises_torn_frame_naming_the_unit(tmp_path):
    path = _store(tmp_path)
    store = OptionalStore(path)
    victim = max(store.entries, key=lambda k: store.entries[k].offset)
    e = store.entries[victim]
    store.close()
    # tear the last frame mid-way AND fix up the manifest's committed
    # length so the skew check at open doesn't fire first — this is the
    # "torn write" case, not the "crash between renames" case
    torn_len = e.offset + e.csize // 2
    with open(path, "r+b") as f:
        f.truncate(torn_len)
    doc = _manifest(path)
    doc["blob_len"] = torn_len
    _rewrite_manifest(path, doc)

    store = OptionalStore(path)
    try:
        with pytest.raises(TornFrameError) as ei:
            store.read_raw(victim)
        assert ei.value.key == victim and victim in str(ei.value)
        with pytest.raises(TornFrameError):
            store.read_raw_many([victim])
        with pytest.raises(TornFrameError):
            store.fetch(victim)
        # every OTHER unit still reads fine — the fault is per-frame
        for k in store.entries:
            if k != victim:
                assert store.fetch(k) is not None
    finally:
        store.close()


def test_manifest_offset_past_eof_is_torn_not_garbage(tmp_path):
    path = _store(tmp_path)
    store = OptionalStore(path)
    victim = sorted(store.entries)[0]
    store.entries[victim].offset = 10**9  # way past EOF
    with pytest.raises(TornFrameError) as ei:
        store.read_raw(victim)
    assert ei.value.key == victim
    store.close()


def test_corrupt_zlib_stream_raises_corrupt_frame_naming_the_unit(tmp_path):
    path = _store(tmp_path)
    man = _manifest(path)
    victim = sorted(man["entries"])[2]
    e = man["entries"][victim]
    with open(path, "r+b") as f:
        f.seek(e["offset"])
        frame = bytearray(f.read(e["csize"]))
        for i in range(min(8, len(frame))):
            frame[i] ^= 0xFF  # wreck the zlib header + first bytes
        f.seek(e["offset"])
        f.write(bytes(frame))

    store = OptionalStore(path)
    try:
        with pytest.raises(CorruptFrameError) as ei:
            store.fetch(victim)
        assert ei.value.key == victim and victim in str(ei.value)
        for k in store.entries:  # blast radius: one frame
            if k != victim:
                assert store.fetch(k) is not None
    finally:
        store.close()


def test_rsize_mismatch_raises_corrupt_frame_never_returns_short_array(tmp_path):
    path = _store(tmp_path)
    doc = _manifest(path)
    victim = sorted(doc["entries"])[1]
    doc["entries"][victim]["rsize"] += 4  # decoded bytes will disagree
    _rewrite_manifest(path, doc)
    store = OptionalStore(path)
    try:
        with pytest.raises(CorruptFrameError) as ei:
            store.fetch(victim)
        assert ei.value.key == victim
    finally:
        store.close()


def test_blob_manifest_skew_detected_at_open(tmp_path):
    """The writer commits blob-then-manifest; a crash between the two
    renames leaves a NEW blob next to the OLD manifest. The old manifest
    records the old blob's committed length, so the mismatch is caught at
    open — before any read could hand out misaligned frames."""
    path = _store(tmp_path, units=_units(seed=1))
    old_manifest = _manifest(path)

    # simulate the crash: a second build's blob rename lands, then death —
    # its manifest never replaces the old one
    path2 = _store(tmp_path, name="next.blob",
                   units=_units(seed=2, n=N_UNITS + 3))
    os.replace(path2, path)  # commit 1 of build 2
    _rewrite_manifest(path, old_manifest)  # commit 2 never happened

    with pytest.raises(StoreSkewError) as ei:
        OptionalStore(path)
    assert "manifest" in str(ei.value).lower()
    # typed under the common base too, so callers can catch one root
    assert isinstance(ei.value, StoreError)


def test_v1_manifest_still_opens_without_skew_check(tmp_path):
    """Back-compat: a v1 manifest (no blob_len) predates the skew check —
    it opens and serves; only per-read torn/corrupt detection applies."""
    path = _store(tmp_path)
    doc = _manifest(path)
    _rewrite_manifest(path, {"version": 1, "entries": doc["entries"]})
    store = OptionalStore(path)
    try:
        assert store.version == 1 and store.blob_len is None
        for k, arr in _units():
            np.testing.assert_array_equal(store.fetch(k), arr)
    finally:
        store.close()


def test_crash_mid_compaction_leaves_only_an_orphaned_partial(tmp_path, monkeypatch):
    """A compaction that dies before its rename-commit leaves the source
    artifact untouched and serveable, plus exactly one ``.partial``
    staging dir that ``orphaned_partials`` finds and ``clean_partials``
    removes (the §10 crash-safety rule applied to the §17 rewrite)."""
    from repro.core.partition import TierDecision, TierPlan, Unit
    from repro.core.entrypoints import SERVING_PROFILE
    from repro.checkpoint import tensorstore_lite as tsl
    import repro.core.retier as retier_mod

    art = tmp_path / "artifact"
    art.mkdir()
    units = _units()
    nbytes = sum(a.nbytes for _, a in units)
    us = tuple(Unit(k, "emb", nbytes=a.nbytes) for k, a in units)
    head = np.ones((4, 4), np.float32)
    plan = TierPlan(
        {"head": TierDecision("head", 0, "leaf", "test", head.nbytes),
         "emb": TierDecision("emb", 1, "rows", "test", nbytes, units=us)},
        SERVING_PROFILE, [])
    tsl.write_bundle(str(art / "tier0"), {"head": head})
    write_store(str(art / "optional.blob"), units)
    before = open(art / "optional.blob", "rb").read()

    def crash(tmp, out):
        raise OSError("simulated crash before rename-commit")

    monkeypatch.setattr(retier_mod, "commit_dir", crash)
    out = str(tmp_path / "artifact-compact")
    with pytest.raises(OSError, match="simulated crash"):
        retier_artifact(str(art), plan, out_dir=out)

    assert not os.path.exists(out)  # never half-published
    orphans = orphaned_partials(str(tmp_path))
    assert [os.path.basename(o) for o in orphans] == ["artifact-compact.partial"]
    assert [os.path.basename(p) for p in clean_partials(str(tmp_path))] == \
        ["artifact-compact.partial"]
    assert orphaned_partials(str(tmp_path)) == []
    # the source artifact is byte-for-byte untouched and still opens
    assert open(art / "optional.blob", "rb").read() == before
    OptionalStore(str(art / "optional.blob")).close()


# ---------------------------------------------------------------------------
# writer API: public manifest result, raw-copy append
# ---------------------------------------------------------------------------

def test_close_returns_public_manifest_and_write_store_uses_it(tmp_path):
    path = str(tmp_path / "w.blob")
    w = OptionalStoreWriter(path)
    assert w.manifest is None  # not committed yet
    w.add("a", np.ones((4, 4), np.float32))
    returned = w.close()
    assert returned is w.manifest and "a" in returned
    man = write_store(str(tmp_path / "w2.blob"), _units())
    assert set(man) == {k for k, _ in _units()}


def test_add_raw_rejects_wrong_length_buffer(tmp_path):
    src = OptionalStore(_store(tmp_path))
    key = sorted(src.entries)[0]
    buf = src.read_raw(key)
    w = OptionalStoreWriter(str(tmp_path / "out.blob"))
    with pytest.raises(TornFrameError):
        w.add_raw(key, buf[:-1], src.entries[key])
    w.add_raw(key, buf, src.entries[key])
    w.close()
    src.close()


# ---------------------------------------------------------------------------
# vectored reads: coalescing is an optimization, never a semantic
# ---------------------------------------------------------------------------

def test_read_raw_many_byte_identical_under_permutation_and_overlap(tmp_path):
    store = OptionalStore(_store(tmp_path))
    try:
        keys = sorted(store.entries)
        per_key = {k: store.read_raw(k) for k in keys}

        rng = np.random.default_rng(7)
        for _ in range(5):  # permuted key order
            perm = list(rng.permutation(keys))
            assert store.read_raw_many(perm) == per_key
        # overlapping batches + duplicate keys within a batch
        a, b = keys[: 5] + keys[: 2], keys[3:]
        got = store.read_raw_many(a)
        got.update(store.read_raw_many(b))
        assert got == per_key
        # subset batches at every gap threshold
        for gap in (0, 1, 64, COALESCE_GAP, 1 << 30):
            assert store.read_raw_many(keys[2:6], gap_threshold=gap) == {
                k: per_key[k] for k in keys[2:6]}
        assert store.read_raw_many([]) == {}
    finally:
        store.close()


def test_gap_threshold_zero_degenerates_to_one_pread_per_frame(tmp_path):
    store = OptionalStore(_store(tmp_path))
    try:
        keys = sorted(store.entries)
        rs = ReadStats()
        store.read_raw_many(keys, gap_threshold=0, stats=rs)
        assert rs.preads == len(keys) == rs.frames
        assert rs.coalesced_bytes == 0 and rs.gap_bytes == 0
        # adjacent frames + a generous gap: ONE pread for the whole batch
        rs2 = ReadStats()
        store.read_raw_many(keys, gap_threshold=COALESCE_GAP, stats=rs2)
        assert rs2.preads == 1 and rs2.frames == len(keys)
        assert rs2.coalesced_bytes == sum(
            store.entries[k].csize for k in keys)
        # cumulative store-level stats saw both calls
        assert store.read_stats.preads == rs.preads + rs2.preads
    finally:
        store.close()


def test_fetch_many_decodes_identically_to_fetch(tmp_path):
    units = _units(seed=3)
    store = OptionalStore(_store(tmp_path, units=units))
    try:
        got = store.fetch_many([k for k, _ in units])
        for k, arr in units:
            np.testing.assert_array_equal(got[k], arr)
            np.testing.assert_array_equal(store.fetch(k), arr)
    finally:
        store.close()


# ---------------------------------------------------------------------------
# compaction: raw-frame copy + co-access layout
# ---------------------------------------------------------------------------

def _artifact(tmp_path, units, resident=()):
    from repro.core.partition import TierDecision, TierPlan, Unit
    from repro.core.entrypoints import SERVING_PROFILE
    from repro.checkpoint import tensorstore_lite as tsl

    art = tmp_path / "artifact"
    art.mkdir(exist_ok=True)
    us = tuple(Unit(k, "emb", nbytes=a.nbytes) for k, a in units)
    head = np.ones((4, 4), np.float32)
    plan = TierPlan(
        {"head": TierDecision("head", 0, "leaf", "test", head.nbytes),
         "emb": TierDecision(
            "emb", 1, "rows", "test", sum(a.nbytes for _, a in units),
            units=us, resident_units=tuple(resident))},
        SERVING_PROFILE, [])
    tsl.write_bundle(str(art / "tier0"), {"head": head})
    write_store(str(art / "optional.blob"), units)
    return str(art), plan


def test_unchanged_plan_compacts_with_zero_recompressions(tmp_path):
    """The §17.1 acceptance: every tier-1 unit of an unchanged plan moves
    as a verbatim raw frame — compressed bytes identical to the source
    store's, recompression counter at zero."""
    units = _units(seed=4)
    art, plan = _artifact(tmp_path, units)
    out = str(tmp_path / "artifact-compact")
    meta = retier_artifact(art, plan, out_dir=out)

    assert meta["compaction"]["raw_copied"] == len(units)
    assert meta["compaction"]["recompressed"] == 0

    src = OptionalStore(os.path.join(art, "optional.blob"))
    dst = OptionalStore(os.path.join(out, "optional.blob"))
    try:
        assert set(src.entries) == set(dst.entries)
        for k in src.entries:
            # frame-for-frame byte identity, not just decoded equality
            assert src.read_raw(k) == dst.read_raw(k)
            es, ed = src.entries[k], dst.entries[k]
            assert (es.csize, es.rsize, es.shape, es.dtype, es.codec) == \
                   (ed.csize, ed.rsize, ed.shape, ed.dtype, ed.codec)
        for k, arr in units:
            np.testing.assert_array_equal(dst.fetch(k), arr)
    finally:
        src.close()
        dst.close()


def test_coaccess_order_chains_clusters_deterministically():
    keys = [f"k{i}" for i in range(6)]
    pairs = {("k0", "k3"): 5, ("k3", "k5"): 4, ("k1", "k2"): 3,
             ("k0", "k1"): 1}
    order = coaccess_order(keys, pairs)
    assert sorted(order) == sorted(keys)
    # strongest pairs end up chained: k0-k3-k5, then k1-k2 merges on via
    # the weak (k0,k1) pair; k4 stays a singleton at its sorted position
    i = {k: j for j, k in enumerate(order)}
    assert i["k3"] == i["k0"] + 1 and i["k5"] == i["k3"] + 1
    assert i["k2"] == i["k1"] + 1
    assert order == coaccess_order(list(reversed(keys)), dict(pairs))
    # ties break on the sorted key pair, so equal counts are stable too
    tied = {("a", "b"): 2, ("c", "d"): 2}
    assert coaccess_order(["d", "c", "b", "a"], tied) == ["a", "b", "c", "d"]


def test_compaction_with_trace_lays_out_coaccess_clusters_adjacent(tmp_path):
    """A traced co-access cluster becomes byte-adjacent in the rewritten
    blob (manifest v2 records the layout source), and the cluster then
    warms with ONE coalesced pread where the build-order blob needs
    several — the rq2 locality claim, pinned as a unit test."""
    units = _units(seed=5)
    keys = [k for k, _ in units]
    art, plan = _artifact(tmp_path, units)

    trace = AccessTrace()
    cluster = [keys[0], keys[3], keys[6]]  # scattered in build order
    trace.request_pairs = {
        (cluster[0], cluster[1]): 9, (cluster[1], cluster[2]): 8}
    trace.batches = 1

    out = str(tmp_path / "artifact-compact")
    meta = retier_artifact(art, plan, out_dir=out, trace=trace)
    assert meta["compaction"]["layout"]["source"] == "coaccess"
    assert meta["compaction"]["recompressed"] == 0

    src = OptionalStore(os.path.join(art, "optional.blob"))
    dst = OptionalStore(os.path.join(out, "optional.blob"))
    try:
        assert dst.layout["source"] == "coaccess"
        assert src.layout["source"] == "build-order"
        # the cluster is contiguous in the new blob: offsets chain exactly
        for a, b in zip(cluster, cluster[1:]):
            ea, eb = dst.entries[a], dst.entries[b]
            assert eb.offset == ea.offset + ea.csize
        # ...so it warms with one pread, vs several from the source layout
        rs_src, rs_dst = ReadStats(), ReadStats()
        got_src = src.read_raw_many(cluster, gap_threshold=0, stats=rs_src)
        got_dst = dst.read_raw_many(cluster, gap_threshold=COALESCE_GAP,
                                    stats=rs_dst)
        assert got_src == got_dst  # raw copy: byte-identical frames
        assert rs_dst.preads == 1 < rs_src.preads == len(cluster)
    finally:
        src.close()
        dst.close()


# ---------------------------------------------------------------------------
# slow tier: codec round-trip property over dtype x shape x level
# ---------------------------------------------------------------------------

# the fault-injection + layout tests above run everywhere; only the
# property search needs hypothesis and skips individually without it
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # pragma: no cover - hypothesis-less environments
    class _NoStrategies:  # chainable no-op: st.lists(...).map(...) etc.
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _NoStrategies()

    class HealthCheck:
        too_slow = None

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)


def _arrays():
    import ml_dtypes

    dtypes = st.sampled_from(
        [np.float32, np.float16, np.int16, np.uint8, np.int64,
         ml_dtypes.bfloat16])
    shapes = st.lists(st.integers(1, 8), min_size=1, max_size=3).map(tuple)

    def build(dt, shape):
        rng = np.random.default_rng(abs(hash((str(dt), shape))) % (2**32))
        if np.dtype(dt).kind in "iu":
            info = np.iinfo(dt)
            return rng.integers(info.min, info.max, size=shape,
                                dtype=dt, endpoint=True)
        return rng.standard_normal(shape).astype(dt)

    return st.builds(build, dtypes, shapes)


@pytest.mark.slow
@settings(max_examples=120, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(arrs=st.lists(_arrays(), min_size=1, max_size=4),
       level=st.integers(min_value=0, max_value=9))
def test_store_round_trip_property(tmp_path_factory, arrs, level):
    """Any dtype x shape x level round-trips bit-exactly through the
    store — including bf16 byte-planing (level>0 on 2-byte dtypes) and
    level=0 raw frames — via fetch, fetch_many, AND a raw-copy compaction
    hop into a second store."""
    tmp = tmp_path_factory.mktemp("prop")
    units = [(f"u{i}", a) for i, a in enumerate(arrs)]
    path = str(tmp / "p.blob")
    write_store(path, units, level=level)
    store = OptionalStore(path)
    copy_path = str(tmp / "copy.blob")
    try:
        expect_codec = "raw" if level == 0 else None
        for k, a in units:
            got = store.fetch(k)
            assert got.dtype == a.dtype and got.shape == a.shape
            np.testing.assert_array_equal(
                got.view(np.uint8), a.view(np.uint8))
            if expect_codec:
                assert store.entries[k].codec == expect_codec
            elif a.dtype.itemsize == 2:
                assert store.entries[k].codec == "zlib-bp"
        many = store.fetch_many([k for k, _ in units])
        for k, a in units:
            np.testing.assert_array_equal(
                many[k].view(np.uint8), a.view(np.uint8))
        # raw-copy hop: frames survive a compaction verbatim
        with OptionalStoreWriter(copy_path) as w:
            for k, _ in units:
                w.add_raw(k, store.read_raw(k), store.entries[k])
        copy = OptionalStore(copy_path)
        try:
            for k, a in units:
                assert copy.read_raw(k) == store.read_raw(k)
                np.testing.assert_array_equal(
                    copy.fetch(k).view(np.uint8), a.view(np.uint8))
        finally:
            copy.close()
    finally:
        store.close()
        for p in (path, path + ".manifest.json",
                  copy_path, copy_path + ".manifest.json"):
            if os.path.exists(p):
                os.remove(p)
