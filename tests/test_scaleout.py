"""Mesh-sharded tiered load (DESIGN.md §15.1).

Per-shard residency accounting without a model: with ``shard_divisors``
attached, a faulted unit charges ceil(nbytes/divisor) against the device
budget while every IO statistic (ensure's return, LoadEvents,
faulted_bytes) keeps raw host bytes — so a budget counts per-device
bytes and the no-mesh path stays byte-identical.

End-to-end: a degenerate 1x1 mesh threaded through ``cold_start`` must
reproduce the unsharded run exactly (outputs, charges, budget). On a
real multi-device geometry the parity contract splits (§15.1): loaded
*bytes* stay bit-identical across geometries, and *outputs* are exact
across modes within a geometry — cross-geometry tokens are only
tolerance-close because GSPMD reorders bf16 partial sums. The 8-device
2x4 geometry needs ``--xla_force_host_platform_device_count`` set before
jax initializes, so it runs in a subprocess and is marked ``slow``
(CI's slow-tests job; see also ``benchmarks/bench_rq11_scaleout``).
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import DeploymentProfile, HostArbiter, analyze, build_artifact
from repro.core.entrypoints import SERVING_PROFILE
from repro.core.on_demand import TieredParams
from repro.core.optional_store import OptionalStore, write_store
from repro.core.partition import TierDecision, TierPlan, Unit
from repro.launch.mesh import make_debug_mesh
from repro.models.zoo import build_model
from repro.serving import GenerationEngine, cold_start

from test_prefetch import COLS, N_UNITS, ROWS, UNIT_BYTES, _leaf_rows, _mini


def _mini_sharded(tmp_path, divisor, budget=None, name="shard"):
    """The test_prefetch _mini harness with a shard divisor on its one
    leaf, as cold_start attaches when a mesh shards the tier-1 plan."""
    rng = np.random.default_rng(0)
    data = rng.standard_normal((N_UNITS * ROWS, COLS)).astype(np.float32)
    units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * ROWS, (g + 1) * ROWS), nbytes=UNIT_BYTES)
        for g in range(N_UNITS)
    )
    dec = TierDecision("emb", 1, "rows", "test", data.nbytes, units=units)
    plan = TierPlan({"emb": dec}, SERVING_PROFILE, [])
    path = str(tmp_path / f"{name}.blob")
    write_store(path, [(u.key, data[u.rows[0]: u.rows[1]]) for u in units])
    tp = TieredParams(
        {"emb": jnp.zeros(data.shape, jnp.float32)}, plan, OptionalStore(path),
        device_budget_bytes=budget, shard_divisors={"emb": divisor},
    )
    return tp, data, units


DIV = 4
CHARGE = -(-UNIT_BYTES // DIV)  # 512: the per-device share of one unit


def test_unit_charge_is_per_shard_bytes(tmp_path):
    tp, _, units = _mini_sharded(tmp_path, DIV)
    assert tp.unit_charge(units[0].key) == CHARGE
    assert tp.unit_charge(units[0].key, nbytes=UNIT_BYTES) == CHARGE
    # ceil: a charge is never rounded down to free
    assert tp.unit_charge(units[0].key, nbytes=1) == 1
    # no divisor → raw bytes
    plain, _, p_units = _mini(tmp_path, name="plain")
    assert plain.unit_charge(p_units[0].key) == UNIT_BYTES


def test_fault_charges_shard_but_reports_raw_bytes(tmp_path):
    tp, data, units = _mini_sharded(tmp_path, DIV)
    moved = tp.ensure([units[0].key, units[1].key])
    # IO statistics stay raw host bytes...
    assert moved == 2 * UNIT_BYTES
    assert tp.stats.request_fault_bytes == 2 * UNIT_BYTES
    assert all(e.nbytes == UNIT_BYTES for e in tp.stats.events)
    # ...while the residency ledger holds per-device charges
    assert tp.residency.resident_bytes == 2 * CHARGE
    assert tp.residency.charged_bytes() == 2 * CHARGE
    np.testing.assert_array_equal(_leaf_rows(tp, units[0]), data[:ROWS])


def test_budget_counts_shard_charges(tmp_path):
    # budget = 3 per-device shares: holds 3 units whose raw bytes (6144)
    # would blow a raw-byte budget of 1536 three times over
    tp, _, units = _mini_sharded(tmp_path, DIV, budget=3 * CHARGE)
    tp.ensure([u.key for u in units[:3]])
    assert len(tp.resident_keys) == 3
    assert tp.residency.resident_bytes == 3 * CHARGE <= tp.residency.budget_bytes
    # one more forces a single eviction, still counted in charge units
    tp.ensure([units[3].key])
    assert len(tp.resident_keys) == 3
    assert tp.residency.resident_bytes == 3 * CHARGE


def test_arbiter_pools_shard_charges_across_tenants(tmp_path):
    """§15.1 in the HostArbiter: a sharded tenant's make_room requests are
    in charge units, so it packs divisor-times more units per host byte."""
    sharded, _, s_units = _mini_sharded(tmp_path, DIV, name="t-shard")
    plain, _, p_units = _mini(tmp_path, name="t-plain")
    arb = HostArbiter(4 * UNIT_BYTES)
    arb.register("sharded", sharded, share=0.5)
    arb.register("plain", plain, share=0.5)
    plain.ensure([p_units[0].key, p_units[1].key])      # 2 * 2048 raw
    sharded.ensure([u.key for u in s_units[:6]])        # 6 * 512 charged
    audit = arb.audit()
    assert audit["tenants"]["plain"]["resident_bytes"] == 2 * UNIT_BYTES
    assert audit["tenants"]["sharded"]["resident_bytes"] == 6 * CHARGE
    assert audit["resident_bytes"] == 2 * UNIT_BYTES + 6 * CHARGE
    assert audit["over_budget"] == 0


@pytest.fixture(scope="module")
def app(tmp_path_factory):
    cfg = get_reduced("mixtral-8x22b").replace(collect_moe_usage=True)
    model = build_model(cfg)
    profile = DeploymentProfile(resident_experts=1, hot_vocab_fraction=0.25,
                                min_tier1_bytes=1024, vocab_row_group=128)
    res = analyze(model, profile, trace_B=1, trace_S=16)
    params = model.init(jax.random.PRNGKey(0))
    outdir = str(tmp_path_factory.mktemp("scaleout"))
    build_artifact(params, res, outdir)
    return cfg, model, res, outdir


def test_one_device_mesh_parity(app):
    """A degenerate 1x1 mesh (every divisor 1) through cold_start must be
    indistinguishable from the unsharded path: same outputs, same charges,
    same preset budget."""
    cfg, model, res, outdir = app
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(7), (6,), 0, cfg.vocab_size))
    runs = {}
    for label, mesh in (("plain", None), ("mesh", make_debug_mesh(1, 1))):
        with cold_start(model, outdir, res, mode="after2",
                        warm_shapes=((1, 6),), mesh=mesh) as server:
            out, _ = GenerationEngine(server, max_seq=16).generate(
                jnp.asarray(prompt[None, :]), 4)
            runs[label] = {
                "out": np.asarray(out[0]),
                "charged": server.tiered.residency.charged_bytes(),
                "faulted": server.tiered.stats.total_loaded_bytes,
                "budget": server.tiered.residency.budget_bytes,
                "divs": dict(server.tiered._shard_div),
            }
    assert all(d == 1 for d in runs["mesh"]["divs"].values())
    np.testing.assert_array_equal(runs["plain"]["out"], runs["mesh"]["out"])
    for k in ("charged", "faulted", "budget"):
        assert runs["plain"][k] == runs["mesh"][k], k


SCALEOUT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys, tempfile
sys.path.insert(0, "src")
import jax, numpy as np
import jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from repro.configs import get_reduced
from repro.core import DeploymentProfile, analyze, build_artifact, write_monolithic
from repro.launch.mesh import make_debug_mesh
from repro.models.zoo import build_model
from repro.optim import init_adamw
from repro.serving import GenerationEngine, cold_start
from repro.utils.tree import flatten_with_paths

cfg = get_reduced("mixtral-8x22b").replace(collect_moe_usage=True)
model = build_model(cfg)
profile = DeploymentProfile(resident_experts=1, hot_vocab_fraction=0.25,
                            min_tier1_bytes=1024, vocab_row_group=128)
res = analyze(model, profile, trace_B=1, trace_S=16)
params = model.init(jax.random.PRNGKey(0))
outdir = tempfile.mkdtemp()
opt = init_adamw(params)
write_monolithic({"params": params, "opt_state": {"m": opt.m, "v": opt.v}}, outdir)
build_artifact(params, res, outdir)
prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (6,), 0, cfg.vocab_size))
mesh = make_debug_mesh(2, 4)

runs = {}
for label, m, mode in (("plain", None, "after2"),
                       ("mesh-full", mesh, "before"),
                       ("mesh", mesh, "after2")):
    with cold_start(model, outdir, res if mode == "after2" else None,
                    mode=mode, warm_shapes=((1, 6),), mesh=m) as server:
        out, _ = GenerationEngine(server, max_seq=16).generate(
            jnp.asarray(prompt[None, :]), 4)
        rec = {"out": np.asarray(out[0])}
        if server.tiered is not None:
            server.tiered.ensure_all()  # resolve everything for tree compare
            rec["charged"] = server.tiered.residency.charged_bytes()
            rec["divs"] = dict(server.tiered._shard_div)
            rec["tree"] = {p: np.asarray(v)
                           for p, v in flatten_with_paths(server.tiered.tree())}
        runs[label] = rec

divs = runs["mesh"]["divs"]
assert any(d > 1 for d in divs.values()), divs
# load parity across geometries: every resolved leaf bit-identical (the
# §15.1 contract — sharded tier-0 load and tier-1 faults are lossless)
for p, v in runs["plain"]["tree"].items():
    np.testing.assert_array_equal(v, runs["mesh"]["tree"][p], err_msg=p)
# mode parity within the geometry: tiered serving under the mesh produces
# exactly the eager sharded baseline's tokens (cross-geometry tokens are
# NOT asserted: GSPMD partial-sum reordering in bf16 shifts logits)
np.testing.assert_array_equal(runs["mesh-full"]["out"], runs["mesh"]["out"])
# the sharded replica charges only its per-device share
assert runs["mesh"]["charged"] < runs["plain"]["charged"], runs
print("SCALEOUT OK divs>1:", sum(1 for d in divs.values() if d > 1))
"""


@pytest.mark.slow
def test_eight_device_sharded_cold_start_parity():
    r = subprocess.run([sys.executable, "-c", SCALEOUT_SCRIPT],
                       capture_output=True, text=True, timeout=600, cwd=".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SCALEOUT OK" in r.stdout
