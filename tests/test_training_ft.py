"""Training substrate: resume determinism, microbatch equivalence, straggler
watchdog, checkpoint GC/atomicity, elastic re-mesh, GPipe (subprocess)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.zoo import build_model
from repro.optim import AdamWConfig, init_adamw
from repro.training import StragglerWatchdog, TrainConfig, Trainer, make_train_step
from repro.utils.tree import flatten_with_paths


def _tc(**kw):
    base = dict(num_steps=12, save_every=4, adamw=AdamWConfig(lr=1e-3))
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases(tmp_path):
    cfg = get_reduced("phi3-medium-14b")
    model = build_model(cfg)
    data = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 32, 4, seed=1))
    r = Trainer(model, _tc(), data, str(tmp_path)).run()
    assert r.losses[-1] < r.losses[0]


def test_preemption_resume_is_bitwise(tmp_path):
    cfg = get_reduced("yi-34b")
    model = build_model(cfg)
    data = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 32, 4, seed=1))
    # preempt at 8, resume to 12
    Trainer(model, _tc(), data, str(tmp_path / "a")).run(8)
    t2 = Trainer(model, _tc(), data, str(tmp_path / "a"))
    r2 = t2.run()
    assert r2.restored_from == 8
    # straight run to 12
    t3 = Trainer(model, _tc(), data, str(tmp_path / "b"))
    t3.run()
    fa = dict(flatten_with_paths(t2.mgr.restore().collections["params"]))
    fb = dict(flatten_with_paths(t3.mgr.restore().collections["params"]))
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]), err_msg=k)


def test_microbatch_equivalence(rng):
    cfg = get_reduced("phi3-medium-14b")
    model = build_model(cfg)
    batch = {
        "tokens": jax.random.randint(rng, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(rng, 1), (4, 16), 0, cfg.vocab_size),
    }
    p = model.init(rng)
    outs = []
    for n_micro in (1, 2, 4):
        tc = TrainConfig(num_steps=10, micro_batches=n_micro,
                         adamw=AdamWConfig(lr=1e-3, clip_norm=0.0))
        step = jax.jit(make_train_step(model, tc))
        p1, _, m = step(p, init_adamw(p), batch)
        outs.append((p1, float(m["loss"])))
    for p1, loss in outs[1:]:
        assert abs(loss - outs[0][1]) < 1e-5
        for (k, a), (_, b) in zip(flatten_with_paths(outs[0][0]), flatten_with_paths(p1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, err_msg=k)


def test_watchdog_flags_stragglers():
    wd = StragglerWatchdog(z_threshold=3.0, warmup_steps=3)
    for i in range(20):
        wd.record(i, 0.1 + 0.001 * (i % 3))
    assert not wd.flagged
    flagged = wd.record(20, 1.5)  # 15x straggler
    assert flagged and wd.flagged[0][0] == 20
    # detector not poisoned: mean stays near 0.1
    assert wd.mean_step_s < 0.2


def test_watchdog_abort_policy():
    wd = StragglerWatchdog(z_threshold=3.0, warmup_steps=2, policy="abort")
    for i in range(10):
        wd.record(i, 0.1)
    with pytest.raises(RuntimeError, match="straggler"):
        wd.record(10, 5.0)


def test_checkpoint_keep_n_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": tree}, blocking=True)
    assert mgr.all_steps() == [3, 4]
    # a stale .partial dir never corrupts restore
    (tmp_path / "step_00000099.partial").mkdir()
    r = mgr.restore()
    assert r.step == 4


def test_elastic_remesh_roundtrip(tmp_path):
    """Restore a checkpoint onto a different mesh (1-device 'elastic
    scale-down') — values must survive the re-layout."""
    from repro.launch.mesh import make_debug_mesh
    from repro.training import reshard_for_mesh

    cfg = get_reduced("yi-34b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"params": params}, blocking=True)
    restored = mgr.restore()
    mesh = make_debug_mesh(1, 1)
    placed = reshard_for_mesh(restored.collections, mesh, model)
    for (k, a), (_, b) in zip(
        flatten_with_paths(params), flatten_with_paths(placed["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=k)


GPIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.training.pipeline import gpipe_forward

mesh = Mesh(np.array(jax.devices()).reshape(4), ("stage",))
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (4, 8, 8)) * 0.3
b = jax.random.normal(jax.random.fold_in(key, 1), (4, 8)) * 0.1
x = jax.random.normal(jax.random.fold_in(key, 2), (6, 2, 8))
stage_fn = lambda p, h: jnp.tanh(h @ p["w"] + p["b"])
out = gpipe_forward(stage_fn, {"w": W, "b": b}, x, mesh)
ref = x
for s in range(4):
    ref = jnp.tanh(ref @ W[s] + b[s])
assert float(jnp.abs(out - ref).max()) < 1e-5
g = jax.grad(lambda p: jnp.sum(gpipe_forward(stage_fn, p, x, mesh) ** 2))({"w": W, "b": b})
gr = jax.grad(lambda p: jnp.sum((lambda h: [h := jnp.tanh(h @ p["w"][s] + p["b"][s]) for s in range(4)][-1])(x) ** 2))({"w": W, "b": b})
assert max(float(jnp.abs(g[k] - gr[k]).max()) for k in g) < 1e-4
print("GPIPE_SUBPROCESS_OK")
"""


def test_gpipe_multi_device_subprocess():
    """Pipeline parallelism on a forced 4-device mesh (subprocess so the
    main test process keeps its single-device view)."""
    r = subprocess.run([sys.executable, "-c", GPIPE_SCRIPT], capture_output=True,
                       text=True, timeout=300, cwd=".")
    assert "GPIPE_SUBPROCESS_OK" in r.stdout, r.stderr[-2000:]
