"""Pallas kernel validation (deliverable c): shape/dtype sweeps + hypothesis
property tests, every kernel vs its pure-jnp ref.py oracle in interpret
mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.tiered_gather.ops import tiered_gather
from repro.kernels.tiered_gather.ref import tiered_gather_ref

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, H, Hkv, hd, causal, window, softcap, dtype
    (2, 128, 128, 4, 2, 64, True, None, None, jnp.float32),
    (1, 256, 256, 8, 8, 64, True, None, 50.0, jnp.float32),
    (2, 100, 100, 4, 1, 32, True, 32, None, jnp.float32),
    (1, 64, 192, 4, 2, 64, False, None, None, jnp.float32),
    (1, 128, 128, 4, 2, 128, True, None, None, jnp.bfloat16),
    (3, 96, 96, 6, 2, 64, True, 48, None, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_oracle(case):
    B, Sq, Sk, H, Hkv, hd, causal, window, softcap, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=softcap, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=10, deadline=None)
@given(
    sq=st.integers(8, 96),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 3]),
    hd=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_property(sq, hkv, g, hd, causal):
    B, H = 2, hkv * g
    ks = jax.random.split(jax.random.PRNGKey(sq * 131 + hd), 3)
    q = jax.random.normal(ks[0], (B, sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, sq, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, sq, hkv, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 8, 2, 64, 1024, False, None),
    (4, 4, 4, 128, 600, False, 50.0),
    (2, 8, 1, 64, 512, True, None),
    (1, 16, 8, 32, 96, False, None),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_vs_oracle(case):
    B, H, Hkv, hd, Skv, rolling, cap = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Skv, Hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Skv, Hkv, hd), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, Skv + 64)
    out = decode_attention(q, kc, vc, kv_len, rolling=rolling, softcap=cap, interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_len, rolling=rolling, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    skv=st.integers(16, 700),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    hd=st.sampled_from([32, 64]),
)
def test_decode_attention_property(skv, hkv, g, hd):
    B, H = 2, hkv * g
    ks = jax.random.split(jax.random.PRNGKey(skv * 7 + hd), 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, skv, hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, skv, hkv, hd), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, skv + 1)
    out = decode_attention(q, kc, vc, kv_len, interpret=True, bk=128)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 128, 256), (1, 100, 96), (3, 512, 512), (1, 7, 16)])
def test_rglru_vs_oracle(shape):
    B, S, W = shape
    ka, kb = jax.random.split(KEY)
    a = jax.random.uniform(ka, (B, S, W), jnp.float32, 0.8, 0.999)
    b = jax.random.normal(kb, (B, S, W), jnp.float32) * 0.1
    out = rglru_scan(a, b, interpret=True)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 300), w=st.integers(8, 200))
def test_rglru_property(s, w):
    ka, kb = jax.random.split(jax.random.PRNGKey(s * 1009 + w))
    a = jax.random.uniform(ka, (1, s, w), jnp.float32, 0.0, 0.999)
    b = jax.random.normal(kb, (1, s, w), jnp.float32)
    out = rglru_scan(a, b, interpret=True, bt=64, bw=64)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# tiered gather
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", [(1024, 64, 32, 128), (500, 128, 17, 100), (64, 8, 4, 16)])
def test_tiered_gather_vs_oracle(case):
    V, D, N, gs = case
    kt, ki, km = jax.random.split(KEY, 3)
    table = jax.random.normal(kt, (V, D), jnp.float32)
    ids = jax.random.randint(ki, (N,), -5, V + 5)
    G = (V + gs - 1) // gs
    mask = jax.random.randint(km, (G,), 0, 2)
    out, miss = tiered_gather(table, ids, mask, group_size=gs, interpret=True)
    rout, rmiss = tiered_gather_ref(table, ids, mask, group_size=gs)
    np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))


@settings(max_examples=10, deadline=None)
@given(v=st.integers(16, 600), n=st.integers(1, 64), gs=st.integers(4, 128))
def test_tiered_gather_property(v, n, gs):
    key = jax.random.PRNGKey(v * 31 + n)
    kt, ki, km = jax.random.split(key, 3)
    table = jax.random.normal(kt, (v, 16), jnp.float32)
    ids = jax.random.randint(ki, (n,), -3, v + 3)
    G = (v + gs - 1) // gs
    mask = jax.random.randint(km, (G,), 0, 2)
    out, miss = tiered_gather(table, ids, mask, group_size=gs, interpret=True)
    rout, rmiss = tiered_gather_ref(table, ids, mask, group_size=gs)
    np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
    # invariant: every miss row is exactly zero
    assert np.all(np.asarray(out)[np.asarray(miss) == 1] == 0)
