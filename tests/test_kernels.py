"""Pallas kernel validation (deliverable c): shape/dtype sweeps + hypothesis
property tests, every kernel vs its pure-jnp ref.py oracle in interpret
mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# the parametrized parity sweeps run everywhere; only the property
# searches need hypothesis and skip individually without it
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - hypothesis-less environments
    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

from repro.kernels.decode_attention.ops import decode_attention, paged_decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.tiered_gather.ops import tiered_gather, tiered_gather_matmul
from repro.kernels.tiered_gather.ref import (
    tiered_gather_matmul_ref,
    tiered_gather_ref,
)
from repro.models.attention import densify_pages

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, H, Hkv, hd, causal, window, softcap, dtype
    (2, 128, 128, 4, 2, 64, True, None, None, jnp.float32),
    (1, 256, 256, 8, 8, 64, True, None, 50.0, jnp.float32),
    (2, 100, 100, 4, 1, 32, True, 32, None, jnp.float32),
    (1, 64, 192, 4, 2, 64, False, None, None, jnp.float32),
    (1, 128, 128, 4, 2, 128, True, None, None, jnp.bfloat16),
    (3, 96, 96, 6, 2, 64, True, 48, None, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_oracle(case):
    B, Sq, Sk, H, Hkv, hd, causal, window, softcap, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=softcap, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=10, deadline=None)
@given(
    sq=st.integers(8, 96),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 3]),
    hd=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_property(sq, hkv, g, hd, causal):
    B, H = 2, hkv * g
    ks = jax.random.split(jax.random.PRNGKey(sq * 131 + hd), 3)
    q = jax.random.normal(ks[0], (B, sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, sq, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, sq, hkv, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 8, 2, 64, 1024, False, None),
    (4, 4, 4, 128, 600, False, 50.0),
    (2, 8, 1, 64, 512, True, None),
    (1, 16, 8, 32, 96, False, None),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_vs_oracle(case):
    B, H, Hkv, hd, Skv, rolling, cap = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Skv, Hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Skv, Hkv, hd), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, Skv + 64)
    out = decode_attention(q, kc, vc, kv_len, rolling=rolling, softcap=cap, interpret=True)
    ref = decode_attention_ref(q, kc, vc, kv_len, rolling=rolling, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    skv=st.integers(16, 700),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    hd=st.sampled_from([32, 64]),
)
def test_decode_attention_property(skv, hkv, g, hd):
    B, H = 2, hkv * g
    ks = jax.random.split(jax.random.PRNGKey(skv * 7 + hd), 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, skv, hkv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, skv, hkv, hd), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, skv + 1)
    out = decode_attention(q, kc, vc, kv_len, interpret=True, bk=128)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 128, 256), (1, 100, 96), (3, 512, 512), (1, 7, 16)])
def test_rglru_vs_oracle(shape):
    B, S, W = shape
    ka, kb = jax.random.split(KEY)
    a = jax.random.uniform(ka, (B, S, W), jnp.float32, 0.8, 0.999)
    b = jax.random.normal(kb, (B, S, W), jnp.float32) * 0.1
    out = rglru_scan(a, b, interpret=True)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 300), w=st.integers(8, 200))
def test_rglru_property(s, w):
    ka, kb = jax.random.split(jax.random.PRNGKey(s * 1009 + w))
    a = jax.random.uniform(ka, (1, s, w), jnp.float32, 0.0, 0.999)
    b = jax.random.normal(kb, (1, s, w), jnp.float32)
    out = rglru_scan(a, b, interpret=True, bt=64, bw=64)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# tiered gather
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", [(1024, 64, 32, 128), (500, 128, 17, 100), (64, 8, 4, 16)])
def test_tiered_gather_vs_oracle(case):
    V, D, N, gs = case
    kt, ki, km = jax.random.split(KEY, 3)
    table = jax.random.normal(kt, (V, D), jnp.float32)
    ids = jax.random.randint(ki, (N,), -5, V + 5)
    G = (V + gs - 1) // gs
    mask = jax.random.randint(km, (G,), 0, 2)
    out, miss = tiered_gather(table, ids, mask, group_size=gs, interpret=True)
    rout, rmiss = tiered_gather_ref(table, ids, mask, group_size=gs)
    np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))


@settings(max_examples=10, deadline=None)
@given(v=st.integers(16, 600), n=st.integers(1, 64), gs=st.integers(4, 128))
def test_tiered_gather_property(v, n, gs):
    key = jax.random.PRNGKey(v * 31 + n)
    kt, ki, km = jax.random.split(key, 3)
    table = jax.random.normal(kt, (v, 16), jnp.float32)
    ids = jax.random.randint(ki, (n,), -3, v + 3)
    G = (v + gs - 1) // gs
    mask = jax.random.randint(km, (G,), 0, 2)
    out, miss = tiered_gather(table, ids, mask, group_size=gs, interpret=True)
    rout, rmiss = tiered_gather_ref(table, ids, mask, group_size=gs)
    np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
    # invariant: every miss row is exactly zero
    assert np.all(np.asarray(out)[np.asarray(miss) == 1] == 0)


# ---------------------------------------------------------------------------
# fused gather-matmul (residency-masked; DESIGN.md §16.1)
# ---------------------------------------------------------------------------

GM_CASES = [
    # V, D, F, N, gs
    (256, 32, 64, 16, 32),
    (500, 64, 48, 33, 17),   # V not a multiple of gs (ragged last group)
    (64, 16, 16, 8, 8),
    (1024, 128, 96, 40, 128),
]


def _gm_inputs(V, D, F, N, seed=0):
    kt, kw, ki = jax.random.split(jax.random.PRNGKey(seed or 42), 3)
    table = jax.random.normal(kt, (V, D), jnp.float32)
    w = jax.random.normal(kw, (D, F), jnp.float32)
    ids = jax.random.randint(ki, (N,), -5, V + 5)
    return table, w, ids


@pytest.mark.parametrize("case", GM_CASES)
def test_gather_matmul_all_resident_matches_dense(case):
    """All groups resident → bit-identical to the dense reference (gather
    then einsum), miss mask all-zero: the fused kernel's fp32-accumulated
    per-row dot is the same arithmetic as the reference matmul."""
    V, D, F, N, gs = case
    table, w, ids = _gm_inputs(V, D, F, N)
    ids = jnp.clip(ids, 0, V - 1)  # keep every row a hit
    G = (V + gs - 1) // gs
    mask = jnp.ones((G,), jnp.int32)
    out, miss = tiered_gather_matmul(table, w, ids, mask, group_size=gs, interpret=True)
    rout, rmiss = tiered_gather_matmul_ref(table, w, ids, mask, group_size=gs)
    np.testing.assert_array_equal(np.asarray(miss), 0)
    np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))


@pytest.mark.parametrize("case", GM_CASES)
def test_gather_matmul_all_cold(case):
    """No group resident → exact zeros everywhere and a full miss mask
    (the loader's fault-and-retry signal)."""
    V, D, F, N, gs = case
    table, w, ids = _gm_inputs(V, D, F, N)
    ids = jnp.clip(ids, 0, V - 1)
    G = (V + gs - 1) // gs
    mask = jnp.zeros((G,), jnp.int32)
    out, miss = tiered_gather_matmul(table, w, ids, mask, group_size=gs, interpret=True)
    np.testing.assert_array_equal(np.asarray(miss), 1)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("case", GM_CASES)
def test_gather_matmul_mixed_residency(case):
    """Random residency + out-of-range ids: output rows match the masked
    reference exactly, every miss row is exactly zero."""
    V, D, F, N, gs = case
    table, w, ids = _gm_inputs(V, D, F, N)
    G = (V + gs - 1) // gs
    mask = jax.random.randint(jax.random.PRNGKey(7), (G,), 0, 2)
    out, miss = tiered_gather_matmul(table, w, ids, mask, group_size=gs, interpret=True)
    rout, rmiss = tiered_gather_matmul_ref(table, w, ids, mask, group_size=gs)
    np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
    assert np.all(np.asarray(out)[np.asarray(miss) == 1] == 0)


def test_gather_matmul_edge_ids_never_oob():
    """Negative ids, ids ≥ V, and exact group-boundary ids are misses or
    exact hits — never an out-of-bounds read (the fetch-id scan must keep
    every DMA'd row inside the table)."""
    V, D, F, gs = 96, 16, 24, 32
    table, w, _ = _gm_inputs(V, D, F, 1)
    # boundary ids: first/last of each group, plus both out-of-range sides
    ids = jnp.asarray([-3, -1, 0, gs - 1, gs, 2 * gs - 1, V - 1, V, V + 7], jnp.int32)
    G = (V + gs - 1) // gs
    for mask in (jnp.ones((G,), jnp.int32),
                 jnp.zeros((G,), jnp.int32),
                 jnp.asarray([1, 0, 1], jnp.int32)):
        out, miss = tiered_gather_matmul(table, w, ids, mask, group_size=gs, interpret=True)
        rout, rmiss = tiered_gather_matmul_ref(table, w, ids, mask, group_size=gs)
        np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
        # out-of-range ids are misses under every mask
        m = np.asarray(miss).reshape(-1)
        assert m[0] == 1 and m[1] == 1 and m[-2] == 1 and m[-1] == 1


@settings(max_examples=10, deadline=None)
@given(
    v=st.integers(16, 300),
    n=st.integers(1, 48),
    gs=st.integers(4, 96),
    d=st.sampled_from([8, 16, 32, 64]),
    f=st.sampled_from([8, 24, 64]),
)
def test_gather_matmul_property(v, n, gs, d, f):
    key = jax.random.PRNGKey(v * 131 + n * 7 + gs)
    kt, kw, ki, km = jax.random.split(key, 4)
    table = jax.random.normal(kt, (v, d), jnp.float32)
    w = jax.random.normal(kw, (d, f), jnp.float32)
    ids = jax.random.randint(ki, (n,), -3, v + 3)
    G = (v + gs - 1) // gs
    mask = jax.random.randint(km, (G,), 0, 2)
    out, miss = tiered_gather_matmul(table, w, ids, mask, group_size=gs, interpret=True)
    rout, rmiss = tiered_gather_matmul_ref(table, w, ids, mask, group_size=gs)
    np.testing.assert_array_equal(np.asarray(miss), np.asarray(rmiss))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
    assert np.all(np.asarray(out)[np.asarray(miss) == 1] == 0)


# ---------------------------------------------------------------------------
# paged-KV flash decode (DESIGN.md §16.2)
# ---------------------------------------------------------------------------


def _paged_inputs(B, Hkv, hd, P, ps, NP, seed=0, permute=True):
    """Random page pool + per-slot page tables (disjoint pages per slot,
    order-permuted when asked — physical order must not matter)."""
    ks = jax.random.split(jax.random.PRNGKey(seed or 42), 4)
    k_pages = jax.random.normal(ks[0], (P, ps, Hkv, hd), jnp.float32)
    v_pages = jax.random.normal(ks[1], (P, ps, Hkv, hd), jnp.float32)
    perm = np.asarray(jax.random.permutation(ks[2], P))
    if not permute:
        perm = np.arange(P)
    assert B * NP <= P, "slots need disjoint pages"
    pt = jnp.asarray(perm[: B * NP].reshape(B, NP), jnp.int32)
    return k_pages, v_pages, pt, ks[3]


PAGED_CASES = [
    # B, Hkv, G, hd, P, ps, NP, rolling, softcap
    (2, 2, 4, 64, 16, 8, 4, False, None),
    (3, 4, 1, 32, 24, 8, 5, False, 30.0),
    (1, 1, 8, 64, 8, 16, 3, False, None),
    (2, 2, 2, 32, 20, 4, 7, True, None),   # rolling wrap
    (4, 2, 3, 16, 32, 8, 6, True, 40.0),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_vs_oracle(case):
    B, Hkv, G, hd, P, ps, NP, rolling, cap = case
    H = Hkv * G
    k_pages, v_pages, pt, kq = _paged_inputs(B, Hkv, hd, P, ps, NP, seed=B * 13 + ps)
    kq1, kq2 = jax.random.split(kq)
    q = jax.random.normal(kq1, (B, H, hd), jnp.float32)
    # cover partial last page and (rolling) beyond-capacity lengths
    hi = NP * ps + (ps if rolling else 0)
    kv_len = jax.random.randint(kq2, (B,), 1, hi + 1)
    out = paged_decode_attention(q, k_pages, v_pages, pt, kv_len,
                                 rolling=rolling, softcap=cap, interpret=True)
    ref = paged_decode_attention_ref(q, k_pages, v_pages, pt, kv_len,
                                     rolling=rolling, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_matches_dense_kernel(case):
    """Densifying the pages into a (B, NP*ps, Hkv, hd) cache and running
    the existing dense masked-decode kernel gives the same answer: the
    paged layout changes WHERE bytes live, not the attention result."""
    B, Hkv, G, hd, P, ps, NP, rolling, cap = case
    H = Hkv * G
    k_pages, v_pages, pt, kq = _paged_inputs(B, Hkv, hd, P, ps, NP, seed=B * 31 + NP)
    kq1, kq2 = jax.random.split(kq)
    q = jax.random.normal(kq1, (B, H, hd), jnp.float32)
    kv_len = jax.random.randint(kq2, (B,), 1, NP * ps + 1)
    out = paged_decode_attention(q, k_pages, v_pages, pt, kv_len,
                                 rolling=rolling, softcap=cap, interpret=True)
    kd = densify_pages(k_pages, pt)
    vd = densify_pages(v_pages, pt)
    dense = decode_attention(q, kd, vd, kv_len, rolling=rolling, softcap=cap,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_table_order_is_physical_not_semantic():
    """Two tables mapping the same logical positions to different physical
    pages (with the pool contents moved accordingly) agree: only the
    logical view enters the softmax."""
    B, Hkv, G, hd, P, ps, NP = 2, 2, 2, 32, 12, 8, 4
    H = Hkv * G
    k_pages, v_pages, pt, kq = _paged_inputs(B, Hkv, hd, P, ps, NP, seed=5)
    q = jax.random.normal(kq, (B, H, hd), jnp.float32)
    kv_len = jnp.asarray([NP * ps, 3 * ps - 2], jnp.int32)
    out = paged_decode_attention(q, k_pages, v_pages, pt, kv_len, interpret=True)
    # relabel physical pages by a permutation and remap the table
    perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(9), P))
    inv = np.argsort(perm)
    k2 = k_pages[perm]
    v2 = v_pages[perm]
    pt2 = jnp.asarray(inv[np.asarray(pt)], jnp.int32)
    out2 = paged_decode_attention(q, k2, v2, pt2, kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6, rtol=1e-6)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    ps=st.sampled_from([4, 8, 16]),
    np_=st.integers(1, 6),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    hd=st.sampled_from([16, 32]),
    lens=st.data(),
    rolling=st.booleans(),
)
def test_paged_decode_property(ps, np_, hkv, g, hd, lens, rolling):
    """Property (§16.2 parity guarantee): for arbitrary (kv_len, page
    size, page-table permutation) — rolling wrap included — the paged
    kernel equals the dense masked reference on the densified cache."""
    B, H = 2, hkv * g
    P = B * np_ + 3  # spare pages: the table must ignore unowned ones
    k_pages, v_pages, pt, kq = _paged_inputs(
        B, hkv, hd, P, ps, np_, seed=ps * 1009 + np_ * 31 + hd
    )
    q = jax.random.normal(kq, (B, H, hd), jnp.float32)
    hi = np_ * ps + (2 * ps if rolling else 0)
    kv_len = jnp.asarray(
        [lens.draw(st.integers(1, hi), label=f"kv_len[{i}]") for i in range(B)],
        jnp.int32,
    )
    out = paged_decode_attention(q, k_pages, v_pages, pt, kv_len,
                                 rolling=rolling, interpret=True)
    kd = densify_pages(k_pages, pt)
    vd = densify_pages(v_pages, pt)
    ref = decode_attention_ref(q, kd, vd, kv_len, rolling=rolling)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)
