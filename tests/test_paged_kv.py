"""Paged KV cache (DESIGN.md §16.2): PagePool allocator invariants and
model-layer parity — ``paged_gqa_decode`` over pool + page table must
produce the same outputs as ``gqa_decode`` over the dense slot cache,
step for step, linear and rolling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.attention import (
    densify_pages,
    gqa_decode,
    paged_gqa_decode,
    paged_kv_write,
)
from repro.serving.paged_kv import PagePool


# ---------------------------------------------------------------------------
# PagePool allocator
# ---------------------------------------------------------------------------


def test_pool_alloc_free_roundtrip():
    pool = PagePool(8, page_size=4, n_slots=3)
    assert pool.pages_for(1) == 1 and pool.pages_for(4) == 1 and pool.pages_for(5) == 2
    assert pool.alloc(0, 9)   # 3 pages
    assert pool.alloc(1, 4)   # 1 page
    assert pool.used_pages == 4 and pool.free_pages == 4
    assert len(pool.owned(0)) == 3 and len(pool.owned(1)) == 1
    pool.assert_consistent()
    assert pool.free(0) == 3
    assert pool.free(0) == 0  # idempotent
    assert pool.used_pages == 1
    pool.assert_consistent()


def test_pool_lifo_reuse():
    """A just-freed slot's pages are the next grant, in the same order —
    deterministic reuse the scheduler tests rely on."""
    pool = PagePool(6, page_size=2, n_slots=2)
    assert pool.alloc(0, 6)
    first = pool.owned(0)
    pool.free(0)
    assert pool.alloc(1, 6)
    assert pool.owned(1) == first


def test_pool_exhaustion_is_atomic():
    """A grant that cannot fully fit takes nothing — no partial grant to
    roll back, slot state untouched."""
    pool = PagePool(4, page_size=4, n_slots=2)
    assert pool.alloc(0, 12)  # 3 of 4 pages
    free_before = pool.free_pages
    assert not pool.alloc(1, 8)  # needs 2, only 1 free
    assert pool.free_pages == free_before
    assert pool.owned(1) == []
    assert pool.stats.exhausted == 1
    pool.assert_consistent()
    # the remaining page still serves a small request
    assert pool.alloc(1, 3)


def test_pool_double_alloc_raises():
    pool = PagePool(4, page_size=4, n_slots=2)
    assert pool.alloc(0, 4)
    with pytest.raises(ValueError, match="already owns"):
        pool.alloc(0, 4)


def test_pool_page_table_layout():
    """(n_slots, NP) int32, logical page order per row, tail padded with
    the slot's LAST page (the kernel's DMA-elision convention), zero rows
    for empty slots."""
    pool = PagePool(8, page_size=4, n_slots=3)
    assert pool.alloc(0, 10)  # 3 pages
    assert pool.alloc(2, 4)   # 1 page
    t = pool.page_table(np_max=4)
    assert t.shape == (3, 4) and t.dtype == np.int32
    own0, own2 = pool.owned(0), pool.owned(2)
    assert list(t[0]) == own0 + [own0[-1]]          # tail repeats last page
    assert list(t[1]) == [0, 0, 0, 0]               # empty slot
    assert list(t[2]) == [own2[0]] + [own2[0]] * 3  # single page repeated


def test_pool_step_kv_positions():
    pool = PagePool(16, page_size=4, n_slots=4)
    assert pool.alloc(0, 16)  # 4 pages granted
    assert pool.alloc(1, 4)   # 1 page
    # slot 0 at 6 live tokens streams only the 2 pages holding them,
    # not its whole 4-page grant; slot 1 streams its single page
    assert pool.step_kv_positions({0: 6, 1: 3}) == 2 * 4 + 1 * 4
    # full-length slot streams its whole grant
    assert pool.step_kv_positions({0: 16}) == 4 * 4


def test_pool_books_detect_corruption():
    pool = PagePool(4, page_size=4, n_slots=2)
    pool.alloc(0, 8)
    pool._free.append(pool.owned(0)[0])  # corrupt: page both free and owned
    with pytest.raises(AssertionError, match="corrupt"):
        pool.assert_consistent()


# ---------------------------------------------------------------------------
# model-layer parity: paged_gqa_decode == gqa_decode
# ---------------------------------------------------------------------------


def _gqa_params(cfg, key):
    D = cfg.d_model
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": jax.random.normal(ks[0], (D, H * hd), jnp.float32) * 0.1,
        "wk": jax.random.normal(ks[1], (D, Hkv * hd), jnp.float32) * 0.1,
        "wv": jax.random.normal(ks[2], (D, Hkv * hd), jnp.float32) * 0.1,
        "wo": jax.random.normal(ks[3], (H * hd, D), jnp.float32) * 0.1,
    }


def _paged_setup(cfg, B, NP, ps, seed=0):
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    pool = PagePool(B * NP + 2, ps, B)
    for b in range(B):
        assert pool.alloc(b, NP * ps)
    pt = jnp.asarray(pool.page_table(np_max=NP))
    P = pool.n_pages
    k_pages = jnp.zeros((P, ps, Hkv, hd), jnp.float32)
    v_pages = jnp.zeros((P, ps, Hkv, hd), jnp.float32)
    return pool, pt, k_pages, v_pages


@pytest.mark.parametrize("rolling_window", [None, 8])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_paged_gqa_decode_matches_dense(rolling_window, use_pallas):
    """Token-for-token parity over a multi-step decode: same outputs, and
    the densified pages equal the dense cache after every write."""
    cfg = get_reduced("mixtral-8x22b")
    B, NP, ps = 2, 2, 4
    Skv = rolling_window if rolling_window else NP * ps
    assert Skv <= NP * ps
    params = _gqa_params(cfg, jax.random.PRNGKey(1))
    _, pt, k_pages, v_pages = _paged_setup(cfg, B, NP, ps)
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k_cache = jnp.zeros((B, Skv, Hkv, hd), jnp.float32)
    v_cache = jnp.zeros((B, Skv, Hkv, hd), jnp.float32)
    # paged capacity may exceed the dense cache; parity holds on the
    # positions both can represent (steps < Skv linear, any step rolling)
    n_steps = Skv + 3 if rolling_window else Skv
    for t in range(n_steps):
        x = jax.random.normal(jax.random.PRNGKey(100 + t), (B, 1, cfg.d_model))
        pos = jnp.full((B,), t, jnp.int32)
        out_d, k_cache, v_cache = gqa_decode(
            params, x, pos, k_cache, v_cache, cfg, rolling_window=rolling_window
        )
        out_p, k_pages, v_pages = paged_gqa_decode(
            params, x, pos, k_pages, v_pages, pt, cfg,
            rolling_window=rolling_window, use_pallas=use_pallas,
        )
        np.testing.assert_allclose(
            np.asarray(out_p), np.asarray(out_d), atol=3e-5, rtol=3e-5,
            err_msg=f"step {t}",
        )
        # the logical prefix both layouts hold must be identical bytes
        kd = densify_pages(k_pages, pt)[:, :Skv]
        np.testing.assert_array_equal(np.asarray(kd), np.asarray(k_cache))


def test_paged_kv_write_targets_only_owned_pages():
    """A write lands at exactly (page_table[b, slot//ps], slot%ps); every
    other page — other slots' and unowned — is untouched."""
    cfg = get_reduced("mixtral-8x22b")
    Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    B, NP, ps = 2, 2, 4
    _, pt, k_pages, v_pages = _paged_setup(cfg, B, NP, ps)
    slot = jnp.asarray([5, 2], jnp.int32)
    k_new = jax.random.normal(jax.random.PRNGKey(3), (B, Hkv, hd))
    v_new = jax.random.normal(jax.random.PRNGKey(4), (B, Hkv, hd))
    k2, v2 = paged_kv_write(k_pages, v_pages, pt, slot, k_new, v_new)
    pt_np = np.asarray(pt)
    touched = {(pt_np[b, int(slot[b]) // ps], int(slot[b]) % ps) for b in range(B)}
    for p in range(k_pages.shape[0]):
        for o in range(ps):
            if (p, o) in touched:
                b = [b for b in range(B)
                     if (pt_np[b, int(slot[b]) // ps], int(slot[b]) % ps) == (p, o)][0]
                np.testing.assert_array_equal(np.asarray(k2[p, o]), np.asarray(k_new[b]))
                np.testing.assert_array_equal(np.asarray(v2[p, o]), np.asarray(v_new[b]))
            else:
                assert np.all(np.asarray(k2[p, o]) == 0)
                assert np.all(np.asarray(v2[p, o]) == 0)
