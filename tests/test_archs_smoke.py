"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates its REDUCED config and runs one train/prefill/decode step
on CPU with shape + finiteness assertions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_reduced, shape_applicable
from repro.models.zoo import build_model

from conftest import rand_batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 16
    spec, _ = model.train_batch_spec(B, S)
    batch = rand_batch(rng, spec, cfg.vocab_size)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.isfinite(g).all()), (arch, path)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode_smoke(arch, rng):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 8
    spec, _ = model.prefill_batch_spec(B, S)
    batch = rand_batch(rng, spec, cfg.vocab_size)
    logits, caches = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    cache = model.init_cache(B, 16, multimodal=True)
    db = {"tokens": jnp.ones((B, 1), jnp.int32), "pos": jnp.zeros((B,), jnp.int32)}
    dl, new_cache = model.decode_step(params, cache, db)
    assert dl.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(dl).all())
    # cache structure is preserved (modulo the serving usage side-output)
    in_paths = {p for p, _ in jax.tree_util.tree_flatten_with_path(cache)[0]}
    out_paths = {
        p for p, _ in jax.tree_util.tree_flatten_with_path(new_cache)[0]
        if "moe_usage" not in str(p)
    }
    assert in_paths == out_paths


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_assignment(arch):
    """The full configs carry the exact assigned numbers (no allocation)."""
    cfg = get_config(arch)
    expected = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_long_500k_applicability():
    """Sub-quadratic gate: long_500k runs for SSM/hybrid/SWA archs only."""
    expected_runs = {"recurrentgemma-9b", "xlstm-125m", "mixtral-8x22b"}
    runs = set()
    for arch in ARCH_IDS:
        ok, reason = shape_applicable(get_config(arch), SHAPES["long_500k"])
        if ok:
            runs.add(arch)
        else:
            assert "full-attention" in reason
    assert runs == expected_runs


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "deepseek-v2-lite-16b"])
def test_moe_active_params_fraction(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    assert model.active_params() < model.num_params()


def test_vlm_text_only_matches_zero_image(rng):
    """Text-only forward == multimodal forward with gate-zero init (cross-attn
    gates start at 0, so image contributions vanish at init)."""
    cfg = get_reduced("llama-3.2-vision-90b")
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 8
    spec, _ = model.prefill_batch_spec(B, S, multimodal=True)
    batch = rand_batch(rng, spec, cfg.vocab_size)
    logits_mm, _ = model.prefill(params, batch)
    batch_text = {"tokens": batch["tokens"]}
    logits_txt, _ = model.prefill(params, batch_text)
    np.testing.assert_allclose(np.asarray(logits_mm), np.asarray(logits_txt), atol=1e-4)
