"""Host-level residency arbiter (DESIGN.md §13).

Covers the arbiter's acceptance contract:
  * ownership inversion — registration disables the tenant's private
    budget (restored at unregister) and every make-room decision becomes
    a global, cross-tenant one;
  * the victim rule — decayed trace heat weighted by shares, pinned and
    LOADING keys of EVERY tenant excluded, per-tenant floors never
    crossed (one hot model cannot starve a neighbour to zero);
  * exact byte bookkeeping under a shared budget (``audit``), at-rest
    budget compliance once pins drop, overshoot accounting when pins +
    floors make the target unreachable;
  * daemon feedback — refault/overshoot rates retune shares (bounded,
    renormalized) and the merged trace history feeds victim scoring;
  * the speculative-load gate — prefetch hints are dropped when they
    would force co-tenant evictions, demand loads never are;
  * arbitrary interleavings of register/ensure/pin/evict/unregister keep
    every invariant (deterministic sequences in the fast suite; the
    hypothesis-driven search and the threaded cross-tenant stress carry
    the ``slow`` marker and run in CI's dedicated job — the same
    20/20-consecutive-runs bar as tests/test_retier_daemon.py).
"""

import os
import tempfile
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AccessTrace,
    HostArbiter,
    OptionalStore,
    Prefetcher,
    RetierDaemon,
    TieredParams,
)
from repro.core.entrypoints import SERVING_PROFILE
from repro.core.optional_store import write_store
from repro.core.param_graph import ReachabilityReport
from repro.core.partition import TierDecision, TierPlan, Unit

ROWS, COLS, N_UNITS = 16, 32, 8
UNIT_BYTES = ROWS * COLS * 4
KEYS = [f"emb#rg{g}" for g in range(N_UNITS)]


def _mini(tmp_path, budget=None, name="mini", seed=0):
    """One row-tiered leaf over a real optional store (the loader state
    machine without a model) — the tests/test_prefetch.py fixture, with a
    per-tenant data seed so cross-tenant byte mixups can't cancel out."""
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((N_UNITS * ROWS, COLS)).astype(np.float32)
    units = tuple(
        Unit(f"emb#rg{g}", "emb", rows=(g * ROWS, (g + 1) * ROWS), nbytes=UNIT_BYTES)
        for g in range(N_UNITS)
    )
    dec = TierDecision("emb", 1, "rows", "test", data.nbytes, units=units)
    plan = TierPlan({"emb": dec}, SERVING_PROFILE, [])
    path = str(tmp_path / f"{name}.blob")
    write_store(path, [(u.key, data[u.rows[0]: u.rows[1]]) for u in units])
    tp = TieredParams(
        {"emb": jnp.zeros(data.shape, jnp.float32)}, plan, OptionalStore(path),
        device_budget_bytes=budget,
    )
    return tp, data, units


def _rows_of(tp, unit):
    lo, hi = unit.rows
    return np.asarray(tp.leaf("emb"))[lo:hi]


# ---------------------------------------------------------------------------
# registration: the ownership inversion
# ---------------------------------------------------------------------------

def test_register_disables_private_budget_unregister_restores(tmp_path):
    tp, _, _ = _mini(tmp_path, budget=3 * UNIT_BYTES)
    arb = HostArbiter(budget_bytes=6 * UNIT_BYTES)
    arb.register("a", tp, share=1.0)
    assert tp.arbiter is arb and tp.tenant_name == "a"
    assert tp.residency.budget_bytes is None      # host governance now
    # the private budget would have evicted here; the host one has room
    tp.ensure(KEYS[:5])
    assert tp.resident_bytes == 5 * UNIT_BYTES
    arb.unregister("a")
    assert tp.arbiter is None and tp.tenant_name == ""
    assert tp.residency.budget_bytes == 3 * UNIT_BYTES   # restored
    # back under private governance: the next release reclaims the excess
    tp.release([])
    assert tp.resident_bytes <= 3 * UNIT_BYTES


def test_register_validation(tmp_path):
    tp1, _, _ = _mini(tmp_path, name="a")
    tp2, _, _ = _mini(tmp_path, name="b")
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp1, floor_bytes=3 * UNIT_BYTES)
    with pytest.raises(ValueError, match="already registered"):
        arb.register("a", tp2)
    with pytest.raises(ValueError, match="already governed"):
        HostArbiter(budget_bytes=UNIT_BYTES).register("x", tp1)
    with pytest.raises(ValueError, match="floors"):
        arb.register("b", tp2, floor_bytes=2 * UNIT_BYTES)  # 3+2 > 4 units
    with pytest.raises(ValueError, match="share"):
        arb.register("b", tp2, share=0.0)
    with pytest.raises(KeyError):
        arb.unregister("never-registered")
    with pytest.raises(ValueError, match="budget_bytes"):
        HostArbiter(budget_bytes=0)


# ---------------------------------------------------------------------------
# the cross-model victim rule
# ---------------------------------------------------------------------------

def test_two_tenants_share_one_budget_cross_eviction(tmp_path):
    tp1, d1, u1 = _mini(tmp_path, name="a", seed=1)
    tp2, d2, u2 = _mini(tmp_path, name="b", seed=2)
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp1)
    arb.register("b", tp2)
    tp1.ensure(KEYS[:4])                      # fills the whole host budget
    assert arb.total_resident_bytes() == 4 * UNIT_BYTES
    tp2.ensure(KEYS[:2])                      # must displace tenant a's units
    assert arb.total_resident_bytes() <= 4 * UNIT_BYTES
    assert tp2.resident_bytes == 2 * UNIT_BYTES
    assert tp1.resident_bytes == 2 * UNIT_BYTES
    assert arb.stats.cross_evictions >= 2
    # evicted rows are placeholder zeros; resident rows are content-exact
    for tp, data, units in ((tp1, d1, u1), (tp2, d2, u2)):
        for u in units:
            expect = (data[u.rows[0]: u.rows[1]] if tp.is_resident(u.key)
                      else np.zeros((ROWS, COLS), np.float32))
            np.testing.assert_array_equal(_rows_of(tp, u), expect)
    arb.audit()


def test_pinned_keys_of_any_tenant_never_evicted(tmp_path):
    tp1, d1, u1 = _mini(tmp_path, name="a", seed=1)
    tp2, _, _ = _mini(tmp_path, name="b", seed=2)
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp1)
    arb.register("b", tp2)
    tp1.ensure(KEYS[:3], pin=True)
    tp2.ensure(KEYS[:4])                      # pressure against a's pins
    for k in KEYS[:3]:
        assert tp1.is_resident(k), f"pinned {k} was evicted cross-tenant"
    for u in u1[:3]:
        np.testing.assert_array_equal(_rows_of(tp1, u), d1[u.rows[0]: u.rows[1]])
    tp1.release(KEYS[:3])
    assert arb.total_resident_bytes() <= 4 * UNIT_BYTES  # rebalance reclaimed


def test_floor_blocks_starvation(tmp_path):
    tp1, _, _ = _mini(tmp_path, name="a", seed=1)
    tp2, _, _ = _mini(tmp_path, name="b", seed=2)
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp1, floor_bytes=2 * UNIT_BYTES)
    arb.register("b", tp2)
    tp1.ensure(KEYS[:3])
    tp2.ensure(KEYS[:6])                      # a hot neighbour wants it all
    # tenant a was squeezed, but never below its floor
    assert tp1.resident_bytes >= 2 * UNIT_BYTES
    assert arb.stats.floor_skips > 0
    assert arb.total_resident_bytes() <= 4 * UNIT_BYTES


def test_overshoot_when_pins_and_floors_block(tmp_path):
    tp1, _, _ = _mini(tmp_path, name="a", seed=1)
    tp2, _, _ = _mini(tmp_path, name="b", seed=2)
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp1)
    arb.register("b", tp2)
    tp1.ensure(KEYS[:4], pin=True)            # budget fully pinned
    tp2.ensure(KEYS[:2], pin=True)            # nothing evictable: overshoot
    assert tp2.resident_bytes == 2 * UNIT_BYTES   # correctness over budget
    assert arb.total_resident_bytes() == 6 * UNIT_BYTES
    assert arb.stats.overshoots >= 2
    assert arb.tenants["b"].overshoots >= 2   # charged to the requester
    tp1.release(KEYS[:4])
    tp2.release(KEYS[:2])
    assert arb.total_resident_bytes() <= 4 * UNIT_BYTES


def test_heat_weighted_victims_prefer_cold_tenant(tmp_path):
    """Trace-derived heat protects a profiled tenant's touched units: the
    victim pass takes the co-tenant's never-touched units first."""
    tp1, _, _ = _mini(tmp_path, name="a", seed=1)
    tp2, _, _ = _mini(tmp_path, name="b", seed=2)
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp1)
    arb.register("b", tp2)
    tp1.start_trace(AccessTrace())
    tp2.ensure(KEYS[:2])                      # b: resident, zero heat
    tp1.ensure(KEYS[:2])                      # a: resident + traced touches
    tp1.ensure(KEYS[:2])                      # warm re-touch -> more heat
    tp1.ensure([KEYS[2]])                     # need 1: must pick from b
    assert tp1.resident_bytes == 3 * UNIT_BYTES
    assert tp2.resident_bytes == 1 * UNIT_BYTES
    # deterministic within the cold tenant: batch-stamp tie broken by key
    assert not tp2.is_resident(KEYS[0])
    assert tp2.is_resident(KEYS[1])


def test_audit_detects_cooked_books(tmp_path):
    tp, _, _ = _mini(tmp_path)
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp)
    tp.ensure(KEYS[:2])
    audit = arb.audit()
    assert audit["resident_bytes"] == 2 * UNIT_BYTES
    assert audit["tenants"]["a"]["resident_bytes"] == 2 * UNIT_BYTES
    tp.residency.resident_bytes += 1          # cook the running counter
    with pytest.raises(AssertionError):
        arb.audit()
    tp.residency.resident_bytes -= 1


# ---------------------------------------------------------------------------
# share feedback + the speculative-load gate
# ---------------------------------------------------------------------------

def test_observe_tick_retunes_shares_toward_pressure(tmp_path):
    tp1, _, _ = _mini(tmp_path, name="a", seed=1)
    tp2, _, _ = _mini(tmp_path, name="b", seed=2)
    arb = HostArbiter(budget_bytes=4 * UNIT_BYTES)
    arb.register("a", tp1, share=1.0)
    arb.register("b", tp2, share=1.0)
    tp1.stats.refaults += 10                  # a is thrashing; b is idle
    arb.observe_tick(tp1)
    arb.observe_tick(tp2)
    shares = arb.shares()
    assert shares["a"] > shares["b"]
    assert shares["a"] + shares["b"] == pytest.approx(2.0)  # renormalized
    assert shares["b"] >= arb.min_share_frac * 2.0          # bounded below
    assert arb.stats.share_updates > 0
    # deltas, not totals: quiet ticks decay the pressure to the floor and
    # the split relaxes back toward the registration shares
    for _ in range(16):
        arb.observe_tick(tp1)
        arb.observe_tick(tp2)
    assert arb.shares()["a"] - arb.shares()["b"] < shares["a"] - shares["b"]
    assert arb.shares()["a"] + arb.shares()["b"] == pytest.approx(2.0)


def test_daemon_tick_feeds_arbiter(tmp_path):
    tp, _, _ = _mini(tmp_path)
    reach = ReachabilityReport(entry_names=["prefill", "decode_step"],
                               reachable={"emb": {"prefill"}})
    arb = HostArbiter(budget_bytes=6 * UNIT_BYTES)
    arb.register("a", tp)
    daemon = RetierDaemon(tp, reach, interval_steps=1, decay=0.5)
    tp.ensure(KEYS[:3])                       # demand traffic into the trace
    assert daemon.tick() is not None
    tenant = arb.tenant_of(tp)
    assert tenant.history is not None         # merged heat handed over
    assert tenant.history.touches            # ...and non-empty
    assert tenant.last_refaults == tp.stats.refaults


def test_prefetch_headroom_gates_speculative_loads_only(tmp_path):
    tp, data, units = _mini(tmp_path)
    arb = HostArbiter(budget_bytes=3 * UNIT_BYTES)
    arb.register("a", tp)
    tp.ensure(KEYS[:3])                       # at budget and at share
    with Prefetcher(tp, batch_units=2) as pf:
        accepted = pf.hint([KEYS[4]])         # would force an eviction
        assert accepted == 0
        assert pf.stats.skipped_headroom == 1
        assert arb.stats.headroom_denials == 1
        tp.evict([KEYS[0]])                   # open one slot
        assert pf.hint([KEYS[4]]) == 1        # now there is headroom
        assert pf.drain()
    assert tp.is_resident(KEYS[4])
    # demand ensure is NEVER gated: it displaces instead
    tp.ensure([KEYS[5]])
    assert tp.is_resident(KEYS[5])
    assert arb.total_resident_bytes() <= 3 * UNIT_BYTES


# ---------------------------------------------------------------------------
# interleaving machinery: shared by the deterministic fast test and the
# hypothesis property test (slow)
# ---------------------------------------------------------------------------

HOST_BUDGET = 6 * UNIT_BYTES
_SHARED: dict = {}


def _shared_stores():
    """Three read-only optional stores written once per process (hypothesis
    examples must not touch function-scoped tmp dirs)."""
    if not _SHARED:
        root = tempfile.mkdtemp(prefix="arbiter_prop_")
        for i in range(3):
            rng = np.random.default_rng(100 + i)
            data = rng.standard_normal((N_UNITS * ROWS, COLS)).astype(np.float32)
            units = tuple(
                Unit(f"emb#rg{g}", "emb", rows=(g * ROWS, (g + 1) * ROWS),
                     nbytes=UNIT_BYTES)
                for g in range(N_UNITS)
            )
            dec = TierDecision("emb", 1, "rows", "test", data.nbytes, units=units)
            plan = TierPlan({"emb": dec}, SERVING_PROFILE, [])
            path = os.path.join(root, f"t{i}.blob")
            write_store(path, [(u.key, data[u.rows[0]: u.rows[1]]) for u in units])
            _SHARED[i] = (path, data, units, plan)
    return _SHARED


def _run_ops(ops):
    """Execute one interleaving of register/ensure/pin/evict/unregister
    against 3 fresh tenants and check every invariant after every op:

      * pinned keys (of every tenant) are always RESIDENT;
      * byte bookkeeping is exact (``audit`` recomputes and raises);
      * the arbiter never evicts a tenant below its floor — only the
        tenant's own explicit ``evict`` may (excluded from that check);
      * with no pins outstanding, total registered resident ≤ budget
        after any byte-moving op (floors are generated small enough that
        an unpinned make-room target is always reachable).
    """
    stores = _shared_stores()
    arb = HostArbiter(budget_bytes=HOST_BUDGET)
    tps = []
    for i in range(3):
        path, data, units, plan = stores[i]
        tps.append(TieredParams(
            {"emb": jnp.zeros((N_UNITS * ROWS, COLS), jnp.float32)},
            plan, OptionalStore(path),
        ))
    registered = [False] * 3
    pinned: list = [[], [], []]               # per-tenant stack of pinned batches
    try:
        for op in ops:
            kind, i = op[0], op[1]
            tp = tps[i]
            before = [t.resident_bytes for t in tps]
            if kind == "register":
                _, _, share, floor_units = op
                if registered[i]:
                    continue
                arb.register(f"t{i}", tp, share=share,
                             floor_bytes=floor_units * UNIT_BYTES)
                registered[i] = True
            elif kind == "unregister":
                if not registered[i] or pinned[i]:
                    continue                  # never orphan a pinned batch
                arb.unregister(f"t{i}")
                registered[i] = False
            elif kind == "ensure":
                _, _, idxs, pin = op
                if not registered[i]:
                    continue
                ks = [KEYS[g] for g in idxs]
                tp.ensure(ks, pin=pin)
                if pin:
                    pinned[i].append(ks)
            elif kind == "release":
                if not pinned[i]:
                    continue
                tp.release(pinned[i].pop())
            elif kind == "evict":
                _, _, idxs = op
                tp.evict([KEYS[g] for g in idxs])

            # invariant 1: no pinned key of ANY tenant was evicted
            for j in range(3):
                for batch in pinned[j]:
                    for k in batch:
                        assert tps[j].is_resident(k), (kind, i, j, k)
            # invariant 2: bookkeeping is exact (audit raises on mismatch)
            arb.audit()
            # invariant 3: floors — only a tenant's own evict may go below
            for j in range(3):
                if registered[j] and not (kind == "evict" and j == i):
                    floor = arb.tenants[f"t{j}"].floor_bytes
                    assert tps[j].resident_bytes >= min(before[j], floor), (
                        kind, i, j, tps[j].resident_bytes, before[j], floor)
            # invariant 4: at rest, the registered set fits the host budget
            if kind in ("ensure", "release", "evict") and not any(pinned):
                total = sum(t.resident_bytes
                            for j, t in enumerate(tps) if registered[j])
                assert total <= HOST_BUDGET, (kind, i, total)
    finally:
        for tp in tps:
            tp.store.close()


def test_interleavings_deterministic_sequences():
    """The canned sequences every property run would shrink toward —
    exercised in the fast tier-1 suite so the machinery never rots."""
    _run_ops([
        ("register", 0, 1.0, 1),
        ("register", 1, 2.0, 1),
        ("ensure", 0, [0, 1, 2, 3], False),
        ("ensure", 1, [0, 1, 2, 3], True),
        ("ensure", 0, [4, 5], True),
        ("release", 1),
        ("evict", 0, [0, 1]),
        ("release", 0),
        ("register", 2, 0.5, 0),
        ("ensure", 2, [6, 7], False),
        ("unregister", 1),
        ("ensure", 2, [0, 1, 2], True),
        ("release", 2),
        ("unregister", 2),
        ("unregister", 0),
    ])
    # pathological: pin everything, then churn the third tenant
    _run_ops([
        ("register", 0, 1.0, 0),
        ("register", 1, 1.0, 0),
        ("ensure", 0, [0, 1, 2], True),
        ("ensure", 1, [0, 1, 2], True),
        ("register", 2, 4.0, 2),
        ("ensure", 2, [0, 1, 2, 3], False),
        ("ensure", 2, [4, 5, 6, 7], False),
        ("release", 0),
        ("release", 1),
        ("evict", 2, [4, 5, 6, 7]),
    ])


@pytest.mark.slow
def test_property_arbitrary_interleavings_hold_invariants():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    unit_idxs = st.lists(st.integers(0, N_UNITS - 1), min_size=1, max_size=4,
                         unique=True)
    op = st.one_of(
        st.tuples(st.just("register"), st.integers(0, 2),
                  st.sampled_from([0.5, 1.0, 2.0]), st.integers(0, 1)),
        st.tuples(st.just("unregister"), st.integers(0, 2)),
        st.tuples(st.just("ensure"), st.integers(0, 2), unit_idxs,
                  st.booleans()),
        st.tuples(st.just("release"), st.integers(0, 2)),
        st.tuples(st.just("evict"), st.integers(0, 2), unit_idxs),
    )

    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(op, min_size=1, max_size=30))
    def check(ops):
        _run_ops(ops)

    check()


# ---------------------------------------------------------------------------
# the threaded cross-tenant stress (the test_retier_daemon.py 20/20 bar)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_stress_three_tenants_pinned_ensure_vs_rebalance(tmp_path):
    """3 tenants x 2 pinned-ensure requester threads racing a rebalance/
    audit loop under a budget half the combined working set. Mid-step, a
    pinned unit must stay RESIDENT with exact bytes no matter which
    tenant's make-room is stealing; at rest, bookkeeping is exact and the
    host budget holds."""
    budget = 6 * UNIT_BYTES
    arb = HostArbiter(budget_bytes=budget)
    tenants = []
    for i in range(3):
        tp, data, units = _mini(tmp_path, name=f"t{i}", seed=10 + i)
        arb.register(f"t{i}", tp, floor_bytes=UNIT_BYTES)
        tenants.append((tp, data, units))
    errors: list = []
    stop = threading.Event()

    def requester(tid, seed):
        tp, data, units = tenants[tid]
        rng = np.random.default_rng(seed)
        try:
            for _ in range(40):
                step = [str(k) for k in rng.choice(KEYS, size=2, replace=False)]
                tp.ensure(step, pin=True)
                try:
                    for k in step:
                        assert tp.is_resident(k), f"pinned {k} not resident"
                        u = units[KEYS.index(k)]
                        got = _rows_of(tp, u)
                        np.testing.assert_array_equal(
                            got, data[u.rows[0]: u.rows[1]],
                            err_msg=f"pinned t{tid}/{k} zeroed mid-step")
                finally:
                    tp.release(step)
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    def rebalancer():
        try:
            while not stop.is_set():
                arb.rebalance()
                arb.audit()
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=requester, args=(tid, 31 * tid + r))
               for tid in range(3) for r in range(2)]
    rt = threading.Thread(target=rebalancer)
    rt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rt.join()

    assert not errors, errors
    assert arb.stats.evictions > 0            # the budget really did bite
    assert arb.stats.cross_evictions > 0      # ...across tenant boundaries
    # at rest: pins all released -> the host budget holds, bookkeeping is
    # exact, and every leaf is either content-exact or placeholder zeros
    audit = arb.audit()
    assert audit["pinned_bytes"] == 0
    assert audit["resident_bytes"] <= budget
    for tp, data, units in tenants:
        res = tp.residency
        assert res.resident_bytes == len(res.resident_keys) * UNIT_BYTES
        for u in units:
            expect = (data[u.rows[0]: u.rows[1]] if tp.is_resident(u.key)
                      else np.zeros((ROWS, COLS), np.float32))
            np.testing.assert_array_equal(_rows_of(tp, u), expect)
        assert tp.resident_bytes >= UNIT_BYTES    # floors held throughout
