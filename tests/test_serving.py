"""Serving integration: cold-start modes, generation parity (the paper's
correctness guarantee: tiered == full), on-demand fault accounting (RQ4),
modal artifacts."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import DeploymentProfile, analyze, build_artifact, write_monolithic
from repro.models.zoo import build_model
from repro.optim import init_adamw
from repro.serving import GenerationEngine, cold_start


def _setup(tmp_path, arch="mixtral-8x22b", **prof_kw):
    cfg = get_reduced(arch).replace(collect_moe_usage=True)
    model = build_model(cfg)
    base = dict(resident_experts=1, hot_vocab_fraction=0.25,
                min_tier1_bytes=1024, vocab_row_group=128)
    base.update(prof_kw)
    profile = DeploymentProfile(**base)
    res = analyze(model, profile, trace_B=1, trace_S=16)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    outdir = str(tmp_path)
    write_monolithic({"params": params, "opt_state": {"m": opt.m, "v": opt.v}}, outdir)
    write_monolithic({"params": params, "opt_state": {"m": opt.m, "v": opt.v}}, outdir, pruned=True)
    build_artifact(params, res, outdir)
    return cfg, model, res, outdir


def test_cold_start_modes_and_parity(tmp_path):
    cfg, model, res, outdir = _setup(tmp_path)
    servers = {}
    for mode in ("before", "after1", "after2"):
        s = cold_start(model, outdir, res if mode == "after2" else None,
                       mode=mode, warm_shapes=((2, 8),))
        servers[mode] = s
        assert s.report.total_s > 0
    # bytes read strictly shrink across the paper's pipeline
    assert servers["before"].report.bytes_read > servers["after1"].report.bytes_read
    assert servers["after1"].report.bytes_read > servers["after2"].report.bytes_read

    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab_size)
    out_full, _ = GenerationEngine(servers["before"], max_seq=32).generate(toks, 6)
    out_tier, st = GenerationEngine(servers["after2"], max_seq=32).generate(toks, 6)
    np.testing.assert_array_equal(out_full, out_tier)
    assert st.faulted_units > 0  # cold experts were faulted in
    assert st.prefill_retries <= 3


def test_strict_residency_still_correct(tmp_path):
    """Even with a fully cold tier-1 (strict policy), generation matches."""
    cfg, model, res, outdir = _setup(tmp_path, resident_experts=0, hot_vocab_fraction=0.0)
    s_full = cold_start(model, outdir, None, mode="before", warm_shapes=((1, 8),))
    s_tier = cold_start(model, outdir, res, mode="after2", warm_shapes=((1, 8),))
    assert s_tier.tiered.resident_fraction() == 0.0
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
    out_full, _ = GenerationEngine(s_full, max_seq=24).generate(toks, 4)
    out_tier, st = GenerationEngine(s_tier, max_seq=24).generate(toks, 4)
    np.testing.assert_array_equal(out_full, out_tier)
    assert st.faulted_bytes > 0


def test_fault_is_one_time_cost(tmp_path):
    """RQ4: the second request over the same routes faults nothing."""
    cfg, model, res, outdir = _setup(tmp_path)
    server = cold_start(model, outdir, res, mode="after2", warm_shapes=((2, 8),))
    eng = GenerationEngine(server, max_seq=32)
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab_size)
    _, st1 = eng.generate(toks, 4)
    _, st2 = eng.generate(toks, 4)
    assert st1.faulted_units > 0
    assert st2.faulted_units == 0
    assert st2.prefill_retries == 0


def test_whisper_text_only_artifact_excludes_encoder(tmp_path, rng):
    cfg = get_reduced("whisper-base")
    model = build_model(cfg)
    profile = DeploymentProfile(modalities=("text",), min_tier1_bytes=256)
    res = analyze(model, profile, trace_B=1, trace_S=8)
    enc = [p for p, d in res.plan.decisions.items() if p.startswith("encoder")]
    assert enc and all(res.plan.decisions[p].tier == 1 for p in enc)
    # text-only serving never touches the encoder -> zero faults
    params = model.init(rng)
    outdir = str(tmp_path)
    build_artifact(params, res, outdir)
    server = cold_start(model, outdir, res, mode="after2", warm_shapes=((1, 8),))
    eng = GenerationEngine(server, max_seq=24)
    toks = jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)
    out, st = eng.generate(toks, 4)
    assert st.faulted_units == 0
    assert out.shape == (1, 4)


def test_stats_policy_reduces_faults(tmp_path):
    """Hot-unit stats preloading (the paper's offline profiling) cuts
    request-time faults vs naive residency."""
    from repro.data import DataConfig, SyntheticTokenPipeline

    arch = "yi-34b"
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 64, 4, seed=5))
    stats = pipe.vocab_row_stats(n_steps=2, row_group=64)
    toks = jnp.asarray(pipe.batch_at(10)["tokens"][:2, :8])

    faults = {}
    for name, hot in (("naive", None), ("stats", stats)):
        profile = DeploymentProfile(hot_vocab_fraction=0.25, min_tier1_bytes=1024,
                                    vocab_row_group=64)
        res = analyze(model, profile, hot_units_stats=hot, trace_B=1, trace_S=8)
        d = str(tmp_path / name)
        build_artifact(params, res, d)
        server = cold_start(model, d, res, mode="after2", warm_shapes=((2, 8),))
        _, st = GenerationEngine(server, max_seq=24).generate(toks, 4)
        faults[name] = st.faulted_units
    assert faults["stats"] <= faults["naive"]
