"""End-to-end training driver (deliverable b): train a ~100M-param xLSTM
for a few hundred steps with the full production substrate — deterministic
data pipeline, AdamW + cosine schedule, async atomic checkpoints, straggler
watchdog, crash-resume.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

Re-running the same command resumes from the latest committed checkpoint
(kill it mid-run to see). The config is the assigned xlstm-125m at reduced
width (CPU container); on a TPU slice, drop --reduced for the real one.
"""

import argparse

import jax

from repro.configs import get_reduced
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.zoo import build_model
from repro.optim import AdamWConfig
from repro.training import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="checkpoints/train_e2e")
    args = ap.parse_args()

    cfg = get_reduced("xlstm-125m").replace(
        d_model=256, num_layers=6, num_heads=4, vocab_size=8192
    )
    model = build_model(cfg)
    print(f"training {cfg.name}: {model.num_params():,} params, "
          f"{args.steps} steps @ batch {args.batch} × seq {args.seq}")

    data = SyntheticTokenPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch, seed=0)
    )
    tcfg = TrainConfig(
        num_steps=args.steps,
        save_every=50,
        warmup_steps=30,
        adamw=AdamWConfig(lr=1e-3),
    )
    trainer = Trainer(model, tcfg, data, args.ckpt)
    result = trainer.run()
    k = max(1, len(result.losses) // 10)
    window = lambda xs: sum(xs) / len(xs)
    print(f"resumed from: {result.restored_from}")
    if result.losses:
        print(f"loss: first-{k} avg {window(result.losses[:k]):.4f} -> "
              f"last-{k} avg {window(result.losses[-k:]):.4f}")
    print(f"straggler flags: {len(result.flagged_steps)}")


if __name__ == "__main__":
    main()
