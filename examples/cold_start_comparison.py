"""Before / after1 / after2 cold starts side by side (paper Table 2 in
miniature), across three model families.

    PYTHONPATH=src python examples/cold_start_comparison.py
"""

import tempfile

import jax

from repro.configs import get_reduced
from repro.core import DeploymentProfile, analyze, build_artifact, write_monolithic
from repro.models.zoo import build_model
from repro.optim import init_adamw
from repro.serving import cold_start

for arch in ("mixtral-8x22b", "whisper-base", "yi-34b"):
    cfg = get_reduced(arch).replace(collect_moe_usage=cfg.moe is not None if (cfg := get_reduced(arch)) else False)
    model = build_model(cfg)
    profile = DeploymentProfile(resident_experts=1, hot_vocab_fraction=0.25,
                                min_tier1_bytes=1 << 12,
                                vocab_row_group=max(64, cfg.vocab_size // 16))
    result = analyze(model, profile)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    outdir = tempfile.mkdtemp(prefix=f"faaslight_{arch}_")
    coll = {"params": params, "opt_state": {"m": opt.m, "v": opt.v}}
    write_monolithic(coll, outdir)
    write_monolithic(coll, outdir, pruned=True)
    build_artifact(params, result, outdir)

    print(f"\n=== {arch} ===")
    base = None
    for mode in ("before", "after1", "after2"):
        jax.clear_caches()
        s = cold_start(model, outdir, result if mode == "after2" else None,
                       mode=mode, warm_shapes=((2, 8),))
        r = s.report
        base = base or r.total_s
        print(f"  {mode:7s} read={r.read_s*1e3:7.1f}ms upload={r.upload_s*1e3:7.1f}ms "
              f"compile={r.compile_s*1e3:7.1f}ms total={r.total_s*1e3:8.1f}ms "
              f"({100*(1-r.total_s/base):+5.1f}%) bytes_read={r.bytes_read:,}")
