"""Quickstart: the FaaSLight pipeline on one model in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced Mixtral, runs the Program Analyzer (entry recognition →
jaxpr reachability → tier plan), writes the two-tier artifact, cold-starts
a server in after2 mode, and serves a request that faults experts in on
demand — the whole paper, miniaturized.
"""

import os
import tempfile

import jax

from repro.configs import get_reduced
from repro.core import DeploymentProfile, analyze, build_artifact
from repro.models.zoo import build_model
from repro.serving import GenerationEngine, cold_start

# 1. the application: a MoE FaaS-style model service
cfg = get_reduced("mixtral-8x22b").replace(collect_moe_usage=True)
model = build_model(cfg)
print(f"model: {cfg.name}, {model.num_params():,} params")

# 2. Program Analyzer: what does this deployment actually need at cold start?
profile = DeploymentProfile(resident_experts=1, hot_vocab_fraction=0.25,
                            min_tier1_bytes=1024, vocab_row_group=128)
result = analyze(model, profile)
s = result.plan.summary()
print(f"tier plan: {s['tier1_leaves']}/{s['leaves']} leaves deferred, "
      f"cold-resident {s['cold_resident_bytes']:,} / {s['tier0_bytes'] + s['tier1_bytes']:,} bytes "
      f"({100*s['cold_resident_bytes']/(s['tier0_bytes']+s['tier1_bytes']):.0f}%)")

# 3. Code Generator: write the two-tier deployment package
params = model.init(jax.random.PRNGKey(0))
outdir = tempfile.mkdtemp(prefix="faaslight_quickstart_")
build_artifact(params, result, outdir)
print("artifact:", sorted(os.listdir(outdir)))

# 4. cold start: tier-0 eager, tier-1 placeholder + hot set
server = cold_start(model, outdir, result, mode="after2", warm_shapes=((2, 8),))
print(f"cold start: read {server.report.read_s*1e3:.1f}ms, "
      f"upload {server.report.upload_s*1e3:.1f}ms, "
      f"compile {server.report.compile_s*1e3:.1f}ms")

# 5. serve: misses fault in on demand (rewrite_template semantics)
engine = GenerationEngine(server, max_seq=32)
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
tokens, stats = engine.generate(prompt, 6)
print(f"generated {tokens.shape}; faulted {stats.faulted_units} units "
      f"({stats.faulted_bytes/2**20:.2f} MiB) in {stats.fault_s*1e3:.1f}ms; "
      f"resident fraction now {server.tiered.resident_fraction():.2f}")
print("tokens:", tokens.tolist())
