"""RQ2 (paper Table 2 / Figs. 5-8): cold-start speedup.

Measures real wall-clock cold starts on the container — disk read (the
preparation phase), host→device upload + placeholder allocation and warm-set
XLA compilation (the loading phase) — for before/after1/after2, n runs
each, with the paper's Mann-Whitney U + Cohen's d reporting.
"""

from __future__ import annotations

import gc

from benchmarks.common import BENCH_ARCHS, csv_row, setup_app, timed_cold_start
from repro.utils.stats import compare


def run(base_dir: str, archs=BENCH_ARCHS, n_runs: int = 5, compile_warm: bool = True) -> list[dict]:
    rows = []
    for arch in archs:
        app = setup_app(arch, base_dir)
        samples: dict[str, dict[str, list[float]]] = {}
        for mode in ("before", "after1", "after2"):
            rec = {"read_s": [], "upload_s": [], "compile_s": [], "total_s": []}
            for _ in range(n_runs):
                # fresh jit cache per run: cold compile is part of the cost
                import jax

                jax.clear_caches()
                gc.collect()
                server = timed_cold_start(app, mode, compile_warm=compile_warm)
                r = server.report
                rec["read_s"].append(r.read_s)
                rec["upload_s"].append(r.upload_s)
                rec["compile_s"].append(r.compile_s)
                rec["total_s"].append(r.total_s)
            samples[mode] = rec
        cmp_total = compare(f"{arch}/total", samples["before"]["total_s"], samples["after2"]["total_s"])
        cmp_load = compare(f"{arch}/load", samples["before"]["upload_s"], samples["after2"]["upload_s"])
        cmp_read = compare(f"{arch}/read", samples["before"]["read_s"], samples["after2"]["read_s"])
        rows.append(
            {
                "arch": arch,
                "samples": samples,
                "total_before_ms": cmp_total.before_mean * 1e3,
                "total_after2_ms": cmp_total.after_mean * 1e3,
                "total_reduction_pct": cmp_total.reduction_pct,
                "read_reduction_pct": cmp_read.reduction_pct,
                "p_value": cmp_total.p_value,
                "effect": cmp_total.effect_size,
                "effect_label": cmp_total.effect_label,
            }
        )
    return rows


def main(base_dir: str, n_runs: int = 5, archs=None, compile_warm: bool = True) -> list[str]:
    out = []
    rows = run(base_dir, archs=archs or BENCH_ARCHS, n_runs=n_runs, compile_warm=compile_warm)
    for r in rows:
        out.append(csv_row(
            f"rq2_cold/{r['arch']}",
            r["total_after2_ms"] * 1e3,
            f"before={r['total_before_ms']:.0f}ms|after2={r['total_after2_ms']:.0f}ms"
            f"|cut={r['total_reduction_pct']:.1f}%|read_cut={r['read_reduction_pct']:.1f}%"
            f"|p={r['p_value']:.4f}|d={r['effect']:.2f}({r['effect_label']})",
        ))
    mean_cut = sum(r["total_reduction_pct"] for r in rows) / len(rows)
    out.append(csv_row("rq2_cold/mean", 0.0, f"total_cut={mean_cut:.1f}%"))
    return out
