"""RQ2 (paper Table 2 / Figs. 5-8): cold-start speedup.

Measures real wall-clock cold starts on the container — disk read (the
preparation phase), host→device upload + placeholder allocation and warm-set
XLA compilation (the loading phase) — for before/after1/after2, n runs
each, with the paper's Mann-Whitney U + Cohen's d reporting.

A second probe measures **cold-read locality** (DESIGN.md §17.2): a
traced co-access cluster scattered through the build-order blob is
compacted into co-access order (raw-frame copy, zero recompressions) and
warmed from both layouts via coalesced vectored reads — fewer preads and
lower read latency, with every decoded array asserted identical to the
pre-compaction artifact.
"""

from __future__ import annotations

import gc
import os
import time

import numpy as np

from benchmarks.common import BENCH_ARCHS, csv_row, setup_app, timed_cold_start
from repro.core import AccessTrace, OptionalStore, retier_artifact
from repro.core.optional_store import COALESCE_GAP, ReadStats
from repro.utils.stats import compare


def locality_probe(app, *, cluster_max: int = 8, n_reads: int = 3):
    """Compact ``app``'s artifact under a synthetic co-access trace and
    measure warming one traced cluster from both layouts.

    The cluster is picked so consecutive members sit more than one
    coalescing gap apart in the BUILD-ORDER blob (scattered — each costs
    its own pread); after co-access compaction they are byte-adjacent and
    warm with one coalesced pread. Returns None when the store is too
    small to scatter a 4-unit cluster."""
    src = OptionalStore(os.path.join(app.outdir, "optional.blob"))
    try:
        by_off = sorted(src.entries, key=lambda k: src.entries[k].offset)
        # greedy scatter: each next member starts > COALESCE_GAP past the
        # previous member's frame end, so the source layout can't coalesce
        cluster: list[str] = []
        for k in by_off:
            if not cluster:
                cluster.append(k)
                continue
            prev = src.entries[cluster[-1]]
            if src.entries[k].offset - (prev.offset + prev.csize) > COALESCE_GAP:
                cluster.append(k)
            if len(cluster) >= cluster_max:
                break
        if len(cluster) < 4:
            return None

        trace = AccessTrace()
        for a, b in zip(cluster, cluster[1:]):
            pair = (a, b) if a < b else (b, a)
            trace.request_pairs[pair] = trace.request_pairs.get(pair, 0) + 4
        trace.batches = 1

        out_dir = app.outdir.rstrip("/") + "-rq2compact"
        t0 = time.perf_counter()
        meta = retier_artifact(app.outdir, app.result.plan,
                               out_dir=out_dir, trace=trace)
        compact_s = time.perf_counter() - t0

        dst = OptionalStore(os.path.join(out_dir, "optional.blob"))
        try:
            def warm(store):
                best, arrs, rs = float("inf"), None, None
                for _ in range(n_reads):
                    r = ReadStats()
                    t0 = time.perf_counter()
                    a = store.fetch_many(cluster, stats=r)
                    best = min(best, time.perf_counter() - t0)
                    arrs, rs = a, r
                return best, arrs, rs

            t_before, arrs_before, rs_before = warm(src)
            t_after, arrs_after, rs_after = warm(dst)

            # correctness gates: compaction moved frames verbatim, and the
            # cluster decodes identically from both layouts
            comp = meta["compaction"]
            assert comp["recompressed"] == 0, comp
            assert comp["layout"]["source"] == "coaccess", comp
            for k in cluster:
                np.testing.assert_array_equal(arrs_before[k], arrs_after[k])
            # the locality win itself: the scattered cluster cost one pread
            # per member; the co-access layout warms it with one pread
            assert rs_after.preads < rs_before.preads, (rs_before, rs_after)
            return {
                "cluster_units": len(cluster),
                "preads_before": rs_before.preads,
                "preads_after": rs_after.preads,
                "coalesced_bytes_after": rs_after.coalesced_bytes,
                "read_ms_before": t_before * 1e3,
                "read_ms_after": t_after * 1e3,
                "raw_copied": comp["raw_copied"],
                "recompressed": comp["recompressed"],
                "compact_s": compact_s,
            }
        finally:
            dst.close()
    finally:
        src.close()


def run(base_dir: str, archs=BENCH_ARCHS, n_runs: int = 5, compile_warm: bool = True) -> list[dict]:
    rows = []
    for arch in archs:
        app = setup_app(arch, base_dir)
        samples: dict[str, dict[str, list[float]]] = {}
        for mode in ("before", "after1", "after2"):
            rec = {"read_s": [], "upload_s": [], "compile_s": [], "total_s": []}
            for _ in range(n_runs):
                # fresh jit cache per run: cold compile is part of the cost
                import jax

                jax.clear_caches()
                gc.collect()
                server = timed_cold_start(app, mode, compile_warm=compile_warm)
                r = server.report
                rec["read_s"].append(r.read_s)
                rec["upload_s"].append(r.upload_s)
                rec["compile_s"].append(r.compile_s)
                rec["total_s"].append(r.total_s)
            samples[mode] = rec
        cmp_total = compare(f"{arch}/total", samples["before"]["total_s"], samples["after2"]["total_s"])
        cmp_load = compare(f"{arch}/load", samples["before"]["upload_s"], samples["after2"]["upload_s"])
        cmp_read = compare(f"{arch}/read", samples["before"]["read_s"], samples["after2"]["read_s"])
        rows.append(
            {
                "arch": arch,
                "samples": samples,
                "total_before_ms": cmp_total.before_mean * 1e3,
                "total_after2_ms": cmp_total.after_mean * 1e3,
                "total_reduction_pct": cmp_total.reduction_pct,
                "read_reduction_pct": cmp_read.reduction_pct,
                "p_value": cmp_total.p_value,
                "effect": cmp_total.effect_size,
                "effect_label": cmp_total.effect_label,
                "locality": locality_probe(app),
            }
        )
    return rows


def main(base_dir: str, n_runs: int = 5, archs=None, compile_warm: bool = True) -> list[str]:
    out = []
    rows = run(base_dir, archs=archs or BENCH_ARCHS, n_runs=n_runs, compile_warm=compile_warm)
    for r in rows:
        out.append(csv_row(
            f"rq2_cold/{r['arch']}",
            r["total_after2_ms"] * 1e3,
            f"before={r['total_before_ms']:.0f}ms|after2={r['total_after2_ms']:.0f}ms"
            f"|cut={r['total_reduction_pct']:.1f}%|read_cut={r['read_reduction_pct']:.1f}%"
            f"|p={r['p_value']:.4f}|d={r['effect']:.2f}({r['effect_label']})",
        ))
    for r in rows:
        loc = r["locality"]
        if loc is None:
            out.append(csv_row(f"rq2_cold/locality/{r['arch']}", 0.0,
                               "skipped: store too small to scatter a cluster"))
            continue
        out.append(csv_row(
            f"rq2_cold/locality/{r['arch']}",
            loc["read_ms_after"] * 1e3,
            f"cluster={loc['cluster_units']}"
            f"|preads {loc['preads_before']}->{loc['preads_after']}"
            f"|read_ms {loc['read_ms_before']:.2f}->{loc['read_ms_after']:.2f}"
            f"|coalesced={loc['coalesced_bytes_after']}B"
            f"|raw_copied={loc['raw_copied']} recompressed={loc['recompressed']}"
            f"|compact_s={loc['compact_s']:.3f}|outputs=identical",
        ))
    mean_cut = sum(r["total_reduction_pct"] for r in rows) / len(rows)
    out.append(csv_row("rq2_cold/mean", 0.0, f"total_cut={mean_cut:.1f}%"))
    return out
