"""RQ3 (paper §5.4): warm-start neutrality + memory benefit + prefetch.

Once the server is resident, tiered serving must not be slower than full
serving (the on-demand machinery is off the warm path), and the resident
parameter bytes are strictly smaller.

Beyond-paper residency layer (DESIGN.md §8): a third server runs the
``stats`` residency preset — device-bytes budget at 50% of tier-1 plus the
async prefetcher — and reports the prefetch hit-rate (fraction of demand
touches hidden by hints) and the p50/p99 miss-stall, i.e. the time a
request-path ``ensure()`` spent blocked on a cold or in-flight unit.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BENCH_ARCHS, csv_row, request_tokens, setup_app, timed_cold_start
from repro.serving import GenerationEngine
from repro.utils.stats import compare


def _warm_latencies(engine, toks, n_runs: int, steps: int = 4) -> list[float]:
    engine.generate(toks, steps)  # warm everything (faults + compiles)
    out = []
    for _ in range(n_runs):
        t0 = time.perf_counter()
        engine.generate(toks, steps)
        out.append(time.perf_counter() - t0)
    return out


def _prefetch_pressure(app, toks, n_runs: int, steps: int = 4) -> dict:
    """Serve under the ``stats`` budget preset: evictions force re-faults,
    hints race them — measure how much latency the prefetcher hides."""
    server = timed_cold_start(app, "after2", residency="stats")
    try:
        engine = GenerationEngine(server, max_seq=32)
        for _ in range(max(2, n_runs)):
            engine.generate(toks, steps)
        if server.prefetcher is not None:
            server.prefetcher.drain(10.0)
        ts = server.tiered.stats
        return {
            "prefetch_hit_rate": ts.prefetch_hit_rate,
            "stall_p50_ms": ts.stall_percentile(50) * 1e3,
            "stall_p99_ms": ts.stall_percentile(99) * 1e3,
            "evictions": ts.evictions,
            "refaults": ts.refaults,
            "budget_bytes": server.tiered.residency.budget_bytes or 0,
            "max_resident_bytes": server.tiered.residency.max_resident_bytes,
        }
    finally:
        server.close()


def run(base_dir: str, archs=BENCH_ARCHS[:4], n_runs: int = 5) -> list[dict]:
    rows = []
    for arch in archs:
        app = setup_app(arch, base_dir)
        toks = request_tokens(app)
        s_full = timed_cold_start(app, "before")
        s_tier = timed_cold_start(app, "after2")
        try:
            lat_full = _warm_latencies(GenerationEngine(s_full, max_seq=32), toks, n_runs)
            lat_tier = _warm_latencies(GenerationEngine(s_tier, max_seq=32), toks, n_runs)
            cmp = compare(f"{arch}/warm", lat_full, lat_tier)
            # memory analogue: device-resident param bytes (tier-0 + live tier-1)
            full_bytes = app.result.plan.total_bytes
            resident = app.result.plan.tier0_bytes + s_tier.tiered.resident_bytes
        finally:
            s_full.close()
            s_tier.close()
        pressure = _prefetch_pressure(app, toks, n_runs)
        rows.append(
            {
                "arch": arch,
                "warm_full_ms": cmp.before_mean * 1e3,
                "warm_tiered_ms": cmp.after_mean * 1e3,
                "delta_pct": -cmp.reduction_pct,
                "p_value": cmp.p_value,
                "neutral": cmp.p_value >= 0.05,
                "resident_bytes_pct": 100.0 * resident / full_bytes,
                **pressure,
            }
        )
    return rows


def main(base_dir: str, n_runs: int = 5) -> list[str]:
    out = []
    for r in run(base_dir, n_runs=n_runs):
        out.append(csv_row(
            f"rq3_warm/{r['arch']}",
            r["warm_tiered_ms"] * 1e3,
            f"full={r['warm_full_ms']:.1f}ms|tiered={r['warm_tiered_ms']:.1f}ms"
            f"|delta={r['delta_pct']:+.1f}%|p={r['p_value']:.3f}"
            f"|neutral={r['neutral']}|resident={r['resident_bytes_pct']:.1f}%"
            f"|pf_hit_rate={r['prefetch_hit_rate']:.2f}"
            f"|stall_p50={r['stall_p50_ms']:.2f}ms|stall_p99={r['stall_p99_ms']:.2f}ms"
            f"|evictions={r['evictions']}",
        ))
    return out
