"""RQ5 (paper Fig. 9 / §5.6): FaaSLight vs the Vulture baseline.

Vulture finds objects that are *defined but never referenced anywhere* —
the checkpoint analogue is a leaf referenced by NO entry of ANY deployment
(global def-use, no per-profile reachability, no sparse-access tiers).
The mixed method = Vulture's identification + our Code Generator
(compressed store + on-demand backstop), as in the paper.

Reported: cold-resident bytes under each method (the latency driver), plus
measured cold starts.
"""

from __future__ import annotations

from benchmarks.common import bench_profile, csv_row, setup_app, timed_cold_start
from repro.core import DeploymentProfile, analyze, build_artifact
from repro.core.partition import TierDecision, TierPlan, Unit
from repro.models.zoo import build_model
from repro.serving import cold_start


def vulture_plan(model, profile) -> TierPlan:
    """Defined-but-unreferenced detection: union reachability over ALL
    entries (every kind, every modality) — the global def-use view."""
    from repro.core.param_graph import build_reachability
    from repro.utils.tree import flatten_with_paths
    import numpy as np

    reach = build_reachability(model.entries(B=1, S=16), model.abstract())
    decisions = {}
    for path, leaf in flatten_with_paths(model.abstract()):
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if reach.reaching(path):
            decisions[path] = TierDecision(path, 0, "leaf", "referenced somewhere", nbytes)
        else:
            decisions[path] = TierDecision(
                path, 1, "leaf", "never referenced", nbytes, units=(Unit(path, path),)
            )
    return TierPlan(decisions=decisions, profile=profile, entry_names=list(reach.entry_names))


ARCHS = ("mixtral-8x22b", "whisper-base", "yi-34b", "llama-3.2-vision-90b")


def run(base_dir: str, archs=ARCHS) -> list[dict]:
    import jax

    rows = []
    for arch in archs:
        app = setup_app(arch, base_dir)
        total = app.result.plan.total_bytes

        vplan = vulture_plan(app.model, app.result.plan.profile)
        vult_resident = vplan.cold_resident_bytes
        faas_resident = app.result.plan.cold_resident_bytes

        # measured: vulture-tiered artifact vs faaslight artifact cold start
        import copy

        vres = copy.copy(app.result)
        vres.plan = vplan
        vdir = app.outdir + "_vulture"
        build_artifact(app.params, vres, vdir)
        jax.clear_caches()
        s_v = cold_start(app.model, vdir, vres, mode="after2", warm_shapes=((2, 8),))
        jax.clear_caches()
        s_f = timed_cold_start(app, "after2")
        jax.clear_caches()
        s_b = timed_cold_start(app, "before")

        rows.append(
            {
                "arch": arch,
                "vulture_resident_pct": 100.0 * vult_resident / total,
                "faaslight_resident_pct": 100.0 * faas_resident / total,
                "vulture_cut_pct": 100.0 * (1 - vult_resident / total),
                "faaslight_cut_pct": 100.0 * (1 - faas_resident / total),
                "cold_before_ms": s_b.report.total_s * 1e3,
                "cold_vulture_ms": s_v.report.total_s * 1e3,
                "cold_faaslight_ms": s_f.report.total_s * 1e3,
            }
        )
    return rows


def main(base_dir: str) -> list[str]:
    out = []
    rows = run(base_dir)
    for r in rows:
        out.append(csv_row(
            f"rq5_comparison/{r['arch']}",
            r["cold_faaslight_ms"] * 1e3,
            f"resident: vulture={r['vulture_resident_pct']:.1f}% "
            f"faaslight={r['faaslight_resident_pct']:.1f}%"
            f"|bytes_cut: vulture={r['vulture_cut_pct']:.1f}% "
            f"faaslight={r['faaslight_cut_pct']:.1f}%"
            f"|cold: before={r['cold_before_ms']:.0f} vult={r['cold_vulture_ms']:.0f} "
            f"faas={r['cold_faaslight_ms']:.0f}ms",
        ))
    v = sum(r["vulture_cut_pct"] for r in rows) / len(rows)
    f = sum(r["faaslight_cut_pct"] for r in rows) / len(rows)
    ratio = f / v if v > 0 else float("inf")
    out.append(csv_row("rq5_comparison/mean", 0.0,
                       f"vulture_cut={v:.1f}%|faaslight_cut={f:.1f}%|improvement={ratio:.1f}x"))
    return out
