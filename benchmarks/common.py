"""Shared benchmark harness: artifact setup, timed cold starts, CSV rows.

Benchmarks run the REDUCED configs (the container is CPU-only); the paper's
relative quantities (size/latency reductions, fault accounting, statistical
tests) are scale-free, and the full-scale story is carried by the dry-run
roofline (benchmarks/roofline.py)."""

from __future__ import annotations

import os
import shutil
import tempfile
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import (
    DeploymentProfile,
    analyze,
    build_artifact,
    write_monolithic,
)
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.zoo import build_model
from repro.optim import init_adamw
from repro.serving import GenerationEngine, cold_start

# benchmark arch set: one per family + the MoE champions
BENCH_ARCHS = (
    "mixtral-8x22b",        # moe (paper's ideal case)
    "deepseek-v2-lite-16b", # moe + mla
    "yi-34b",               # dense
    "whisper-base",         # enc-dec modal split
    "llama-3.2-vision-90b", # vlm modal split
    "recurrentgemma-9b",    # hybrid
)


def bench_profile(cfg) -> DeploymentProfile:
    return DeploymentProfile(
        resident_experts=1,
        hot_vocab_fraction=0.25,
        min_tier1_bytes=1 << 12,
        vocab_row_group=max(64, cfg.vocab_size // 16),
    )


@dataclass
class App:
    arch: str
    cfg: object
    model: object
    params: dict
    result: object  # AnalysisResult
    outdir: str


_APP_CACHE: dict = {}


def setup_app(arch: str, base_dir: str, *, profile=None, stats=True) -> App:
    key = (arch, base_dir, profile is None)
    if key in _APP_CACHE:
        return _APP_CACHE[key]
    cfg = get_reduced(arch).replace(collect_moe_usage=True)
    model = build_model(cfg)
    profile = profile or bench_profile(cfg)
    hot = None
    if stats:
        pipe = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 64, 4, seed=11))
        hot = pipe.vocab_row_stats(n_steps=2, row_group=profile.vocab_row_group)
    result = analyze(model, profile, hot_units_stats=hot, trace_B=1, trace_S=16)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    outdir = os.path.join(base_dir, arch)
    os.makedirs(outdir, exist_ok=True)
    collections = {"params": params, "opt_state": {"m": opt.m, "v": opt.v}}
    write_monolithic(collections, outdir)
    write_monolithic(collections, outdir, pruned=True)
    build_artifact(params, result, outdir)
    app = App(arch, cfg, model, params, result, outdir)
    _APP_CACHE[key] = app
    return app


def timed_cold_start(app: App, mode: str, *, warm_shape=(2, 8), compile_warm=True, **cold_kw):
    """``cold_kw`` passes through to ``cold_start`` (residency preset,
    device budget, prefetch toggles — see serving.cold_start). An explicit
    ``warm_shapes`` in ``cold_kw`` overrides the single ``warm_shape``
    (e.g. to also pre-compile the max_seq decode cache for TTFT runs)."""
    warm_shapes = cold_kw.pop("warm_shapes", (warm_shape,))
    return cold_start(
        app.model, app.outdir, app.result if mode == "after2" else None,
        mode=mode, warm_shapes=warm_shapes, compile_warm_set=compile_warm,
        **cold_kw,
    )


def request_tokens(app: App, B: int = 2, S: int = 8):
    return jax.random.randint(jax.random.PRNGKey(17), (B, S), 0, app.cfg.vocab_size)


def artifact_bytes(app: App, mode: str) -> int:
    if mode == "before":
        return os.path.getsize(os.path.join(app.outdir, "before.bin"))
    if mode == "after1":
        return os.path.getsize(os.path.join(app.outdir, "after1.bin"))
    total = 0
    for f in ("tier0.bin", "optional.blob", "optional.blob.manifest.json", "artifact.json"):
        p = os.path.join(app.outdir, f)
        if os.path.exists(p):
            total += os.path.getsize(p)
    return total


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
