"""RQ9 (beyond-paper, DESIGN.md §13): can N models share ONE host device
budget — the FaaSLight density story — without changing a single output
token, and what does aggregate latency pay per extra co-tenant?

FaaSLight's economics come from packing many functions per host; the
cold-start taxonomy literature identifies per-host density as the primary
driver of cold-start frequency. Until the ``HostArbiter`` every model
policed a *private* device budget — N co-resident models could jointly
exceed the host without anyone noticing. Here N small models are served
concurrently under one arbiter-owned budget (50% of their summed tier-1
bytes — real cross-tenant eviction pressure) and we measure the
aggregate-latency-vs-models-per-host curve:

  * **solo baselines** — each model served alone, unlimited budget: the
    reference outputs and per-model reference latency;
  * **zoo passes** — for n = 1..N, the first n models cold-start against
    one shared ``HostArbiter`` (presets resolve to *shares*: every tenant
    gets an equal slice-weight) and serve their request sets on
    concurrent threads while the arbiter steals budget back and forth.

Correctness gates, asserted before any number is reported:
  * every model's tokens under the shared budget are IDENTICAL to its
    solo run (cross-tenant eviction is a latency event, never a failure);
  * the arbiter's audit passes (exact per-tenant byte bookkeeping) and
    at-rest resident bytes fit the host budget once all pins drop.

Standalone: ``python -m benchmarks.bench_rq9_zoo [--smoke] [--json-out F]``
(wired into benchmarks/run.py as the ``rq9`` section and the CI smoke).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, setup_app, timed_cold_start
from repro.core import HostArbiter, OptionalStore
from repro.serving import GenerationEngine

# three small families: MoE, dense, dense-GQA — disjoint artifacts, one host
ZOO_ARCHS = ("mixtral-8x22b", "yi-34b", "phi3-medium-14b")


def _prompts(app, *, n: int, prompt_len: int):
    return [
        np.asarray(jax.random.randint(jax.random.PRNGKey(900 + 17 * i),
                                      (prompt_len,), 0, app.cfg.vocab_size))
        for i in range(n)
    ]


def _serve(server, prompts, gen_steps: int, max_seq: int):
    eng = GenerationEngine(server, max_seq=max_seq)
    outs = []
    for p in prompts:
        out, _ = eng.generate(jnp.asarray(p[None, :]), gen_steps)
        outs.append(np.asarray(out[0]))
    return outs


def run(
    base_dir: str,
    archs=ZOO_ARCHS,
    *,
    prompt_len: int = 8,
    gen_steps: int = 6,
    n_requests: int = 2,
    budget_frac: float = 0.5,
    sizes=None,  # which zoo sizes to run (default 1..len(archs))
) -> dict:
    apps = [setup_app(a, base_dir) for a in archs]
    max_seq = prompt_len + gen_steps + 2
    prompts = {a.arch: _prompts(a, n=n_requests, prompt_len=prompt_len) for a in apps}

    # -- solo baselines: each model alone, unlimited budget -------------------
    solo_outs, solo_s = {}, {}
    for app in apps:
        t0 = time.perf_counter()
        with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                              compile_warm=False, prefetch=False) as server:
            solo_outs[app.arch] = _serve(server, prompts[app.arch], gen_steps, max_seq)
        solo_s[app.arch] = time.perf_counter() - t0

    # -- zoo passes: first n models under ONE arbiter-owned budget ------------
    sizes = list(sizes) if sizes else list(range(1, len(apps) + 1))
    curve = []
    for n in sizes:
        group = apps[:n]
        tier1 = {a.arch: a.result.plan.tier1_bytes for a in group}
        # floors keep every tenant able to hold its two largest units even
        # when a hot neighbour squeezes it (the starvation guarantee)
        floors = {}
        for a in group:
            store = OptionalStore(os.path.join(a.outdir, "optional.blob"))
            floors[a.arch] = 2 * max(
                (e.rsize for e in store.entries.values()), default=0)
            store.close()
        budget = max(int(budget_frac * sum(tier1.values())), sum(floors.values()))
        arb = HostArbiter(budget_bytes=budget)
        servers = []
        try:
            for a in group:
                servers.append(timed_cold_start(
                    a, "after2", warm_shape=(1, prompt_len), compile_warm=False,
                    residency="stats", prefetch=False,
                    host_arbiter=arb, tenant_name=a.arch,
                    tenant_floor_bytes=floors[a.arch],
                ).__enter__())
            zoo_outs: dict = {}
            errors: list = []

            def _worker(app, server):
                try:
                    zoo_outs[app.arch] = _serve(
                        server, prompts[app.arch], gen_steps, max_seq)
                except Exception as e:  # surfaced below; a silent thread
                    errors.append((app.arch, repr(e)))  # death would "pass"

            threads = [
                threading.Thread(target=_worker, args=(a, s), daemon=True)
                for a, s in zip(group, servers)
            ]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall_s = time.perf_counter() - t0
            assert not errors, f"serving threads failed: {errors}"

            # gate 1: per-model output parity with the solo baselines
            for a in group:
                for got, ref in zip(zoo_outs[a.arch], solo_outs[a.arch]):
                    np.testing.assert_array_equal(got, ref)
            # gate 2: exact bookkeeping + at-rest budget (pins all dropped)
            audit = arb.audit()
            assert audit["pinned_bytes"] == 0, audit
            assert audit["resident_bytes"] <= budget, audit
            stats = arb.stats.to_dict()
        finally:
            for s in servers:
                s.__exit__(None, None, None)
        curve.append({
            "models": n,
            "budget_bytes": budget,
            "wall_s": wall_s,
            "solo_sum_s": sum(solo_s[a.arch] for a in group),
            "resident_bytes_at_rest": audit["resident_bytes"],
            "evictions": stats["evictions"],
            "cross_evictions": stats["cross_evictions"],
            "overshoots": stats["overshoots"],
        })

    return {
        "archs": [a.arch for a in apps],
        "n_requests": n_requests,
        "gen_steps": gen_steps,
        "budget_frac": budget_frac,
        "curve": curve,
        "outputs_identical": True,
    }


def main(base_dir: str, *, smoke: bool = False, archs=None) -> list[str]:
    archs = archs or ZOO_ARCHS
    kw = dict(gen_steps=4, sizes=[len(archs)]) if smoke else {}
    r = run(base_dir, archs, **kw)
    rows = []
    for pt in r["curve"]:
        rows.append(csv_row(
            f"rq9_zoo/{pt['models']}-models",
            pt["wall_s"] * 1e6,
            f"budget={pt['budget_bytes']}B"
            f"|wall_s={pt['wall_s']:.3f} solo_sum_s={pt['solo_sum_s']:.3f}"
            f"|evictions={pt['evictions']} cross={pt['cross_evictions']} "
            f"overshoots={pt['overshoots']}"
            f"|resident_at_rest={pt['resident_bytes_at_rest']}B"
            f"|outputs=identical",
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one 3-model pass, 2 prompts x 4 steps each")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    ap.add_argument("--json-out", default="",
                    help="also write the CSV rows as a JSON list here")
    args = ap.parse_args()
    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_rq9_")
    print("name,us_per_call,derived")
    rows = main(scratch, smoke=args.smoke)
    for row in rows:
        print(row)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"section": "rq9", "rows": rows}, f, indent=2)
    sys.exit(0)
