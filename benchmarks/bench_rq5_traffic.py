"""RQ5-traffic (beyond-paper, DESIGN.md §9): request throughput and
per-request latency of the continuous-batching scheduler vs. the
sequential one-request-at-a-time engine, on the same cold-started server
state.

Both sides serve the SAME request set (N prompts arriving at t=0) against
an ``after2`` two-tier server, twice each: a **cold pass** that pays the
one-time costs (jit tracing, XLA compiles, tier-1 fault-in — RQ2/RQ4's
territory), then the **warm pass** that measures what the host actually
*sustains*. Sequential latency for request *i* is the FIFO-queue latency
(its own service time plus every predecessor's) — the apples-to-apples
number for "all arrived at once". Greedy outputs are asserted identical
per request, on both passes, before any number is reported.

Standalone: ``python -m benchmarks.bench_rq5_traffic [--smoke]``
(also wired into benchmarks/run.py as the ``traffic`` section; ``--smoke``
is the CI entry next to the rq2 smoke).
"""

from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, setup_app, timed_cold_start
from repro.serving import ContinuousBatchingScheduler, GenerationEngine, SchedulerStats


def run(
    base_dir: str,
    arch: str = "mixtral-8x22b",
    *,
    concurrency: int = 4,
    n_requests: int = 8,
    prompt_len: int = 8,
    gen_steps: int = 16,
) -> dict:
    app = setup_app(arch, base_dir)
    max_seq = prompt_len + gen_steps + 2
    prompts = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (prompt_len,), 0, app.cfg.vocab_size))
        for i in range(n_requests)
    ]

    # -- sequential baseline: one generate() per request, FIFO ----------------
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len)) as server:
        eng = GenerationEngine(server, max_seq=max_seq)

        def seq_pass():
            outs, lat, elapsed = [], [], 0.0
            t0 = time.perf_counter()
            for p in prompts:
                t_req = time.perf_counter()
                out, _ = eng.generate(jnp.asarray(p[None, :]), gen_steps)
                elapsed += time.perf_counter() - t_req
                lat.append(elapsed)  # FIFO: waits behind every predecessor
                outs.append(np.asarray(out[0]))
            return outs, lat, time.perf_counter() - t0

        seq_out, _, wall_seq_cold = seq_pass()
        seq_out2, seq_lat, wall_seq = seq_pass()

    # -- continuous batching on an identically cold server --------------------
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len)) as server:
        eng = GenerationEngine(server, max_seq=max_seq)
        sched = ContinuousBatchingScheduler(eng, max_batch=concurrency)

        def cb_pass():
            t0 = time.perf_counter()
            reqs = [sched.submit(p, gen_steps) for p in prompts]
            sched.run()
            return reqs, time.perf_counter() - t0

        reqs_cold, wall_cb_cold = cb_pass()
        sched.stats = SchedulerStats()  # report steady-state counters only
        reqs, wall_cb = cb_pass()
        stats = sched.stats

    for pass_reqs, pass_refs in ((reqs_cold, seq_out), (reqs, seq_out2)):
        for r, ref in zip(pass_reqs, pass_refs):
            if r.error is not None:
                raise RuntimeError(f"request {r.rid} failed: {r.error}")
            np.testing.assert_array_equal(r.output, ref)

    cb_lat = np.array([r.latency_s for r in reqs])
    return {
        "arch": arch,
        "concurrency": concurrency,
        "n_requests": n_requests,
        "gen_steps": gen_steps,
        "wall_seq_s": wall_seq,
        "wall_cb_s": wall_cb,
        "rps_seq": n_requests / wall_seq,
        "rps_cb": n_requests / wall_cb,
        "speedup": wall_seq / wall_cb,
        "cold_speedup": wall_seq_cold / wall_cb_cold,
        "seq_p50_ms": float(np.percentile(seq_lat, 50) * 1e3),
        "seq_p99_ms": float(np.percentile(seq_lat, 99) * 1e3),
        "cb_p50_ms": float(np.percentile(cb_lat, 50) * 1e3),
        "cb_p99_ms": float(np.percentile(cb_lat, 99) * 1e3),
        "steps": stats.steps,
        "step_faults": stats.faulted_units,
        "max_active": stats.max_active,
    }


def main(base_dir: str, *, smoke: bool = False) -> list[str]:
    kw = dict(n_requests=4, gen_steps=6) if smoke else {}
    r = run(base_dir, **kw)
    return [
        csv_row(
            f"rq5_traffic/{r['arch']}/c{r['concurrency']}",
            r["wall_cb_s"] / r["n_requests"] * 1e6,
            f"throughput={r['rps_cb']:.2f}req/s vs sequential {r['rps_seq']:.2f} "
            f"(sustained speedup {r['speedup']:.2f}x; cold-pass {r['cold_speedup']:.2f}x)"
            f"|lat_p50={r['cb_p50_ms']:.0f}ms p99={r['cb_p99_ms']:.0f}ms "
            f"(seq p50={r['seq_p50_ms']:.0f} p99={r['seq_p99_ms']:.0f})"
            f"|steps={r['steps']}|step_faults={r['step_faults']}"
            f"|outputs=identical",
        ),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 4 requests x 6 steps at concurrency 4")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    args = ap.parse_args()
    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_traffic_")
    print("name,us_per_call,derived")
    for row in main(scratch, smoke=args.smoke):
        print(row)
    sys.exit(0)
