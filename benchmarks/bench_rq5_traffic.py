"""RQ5-traffic (beyond-paper, DESIGN.md §9): request throughput and
per-request latency of the continuous-batching scheduler vs. the
sequential one-request-at-a-time engine, on the same cold-started server
state.

Both sides serve the SAME request set (N prompts arriving at t=0) against
an ``after2`` two-tier server, twice each: a **cold pass** that pays the
one-time costs (jit tracing, XLA compiles, tier-1 fault-in — RQ2/RQ4's
territory), then the **warm pass** that measures what the host actually
*sustains*. Sequential latency for request *i* is the FIFO-queue latency
(its own service time plus every predecessor's) — the apples-to-apples
number for "all arrived at once". Greedy outputs are asserted identical
per request, on both passes, before any number is reported.

Standalone: ``python -m benchmarks.bench_rq5_traffic [--smoke]``
(also wired into benchmarks/run.py as the ``traffic`` section; ``--smoke``
is the CI entry next to the rq2 smoke).
"""

from __future__ import annotations

import argparse
import sys
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, setup_app, timed_cold_start
from benchmarks.roofline import decode_kv_bytes
from repro.serving import (
    ContinuousBatchingScheduler,
    FIFOAdmission,
    GenerationEngine,
    SchedulerStats,
    SLOAdmission,
)


def run(
    base_dir: str,
    arch: str = "mixtral-8x22b",
    *,
    concurrency: int = 4,
    n_requests: int = 8,
    prompt_len: int = 8,
    gen_steps: int = 16,
) -> dict:
    app = setup_app(arch, base_dir)
    max_seq = prompt_len + gen_steps + 2
    prompts = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(100 + i), (prompt_len,), 0, app.cfg.vocab_size))
        for i in range(n_requests)
    ]

    # -- sequential baseline: one generate() per request, FIFO ----------------
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len)) as server:
        eng = GenerationEngine(server, max_seq=max_seq)

        def seq_pass():
            outs, lat, elapsed = [], [], 0.0
            t0 = time.perf_counter()
            for p in prompts:
                t_req = time.perf_counter()
                out, _ = eng.generate(jnp.asarray(p[None, :]), gen_steps)
                elapsed += time.perf_counter() - t_req
                lat.append(elapsed)  # FIFO: waits behind every predecessor
                outs.append(np.asarray(out[0]))
            return outs, lat, time.perf_counter() - t0

        seq_out, _, wall_seq_cold = seq_pass()
        seq_out2, seq_lat, wall_seq = seq_pass()

    # -- continuous batching on an identically cold server --------------------
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len)) as server:
        eng = GenerationEngine(server, max_seq=max_seq)
        # page size 4 makes the §16.2 accounting granular enough to see
        # per-request length (the default 16 ≈ this benchmark's max_seq);
        # the pool still covers max_batch × max_seq, so admission and
        # outputs are untouched
        sched = ContinuousBatchingScheduler(eng, max_batch=concurrency,
                                            kv_page_size=4)

        def cb_pass():
            t0 = time.perf_counter()
            reqs = [sched.submit(p, gen_steps) for p in prompts]
            sched.run()
            return reqs, time.perf_counter() - t0

        reqs_cold, wall_cb_cold = cb_pass()
        sched.stats = SchedulerStats()  # report steady-state counters only
        reqs, wall_cb = cb_pass()
        stats = sched.stats

    for pass_reqs, pass_refs in ((reqs_cold, seq_out), (reqs, seq_out2)):
        for r, ref in zip(pass_reqs, pass_refs):
            if r.error is not None:
                raise RuntimeError(f"request {r.rid} failed: {r.error}")
            np.testing.assert_array_equal(r.output, ref)

    cb_lat = np.array([r.latency_s for r in reqs])
    # paged-KV gate (DESIGN.md §16.2): KV bytes one decode step streams at
    # max shape (the executed masked decode) vs. what the paged layout
    # streams (occupied pages of active slots only) — reported only AFTER
    # the output-identity asserts above, so "reduced bytes/step" can never
    # ride on changed outputs
    kvkw = dict(
        num_layers=app.cfg.num_layers,
        num_kv_heads=app.cfg.num_kv_heads,
        head_dim=app.cfg.resolved_head_dim,
        dtype_bytes=jnp.dtype(app.cfg.dtype).itemsize,
    )
    steps = max(stats.steps, 1)
    kv_dense = decode_kv_bytes(stats.kv_tokens_dense, **kvkw) / steps
    kv_paged = decode_kv_bytes(stats.kv_tokens_paged, **kvkw) / steps
    if not kv_paged < kv_dense:  # the §16.2 gate: fewer bytes, same outputs
        raise RuntimeError(
            f"paged KV streamed no fewer bytes/step than max shape "
            f"({kv_paged:.0f} vs {kv_dense:.0f})"
        )
    return {
        "arch": arch,
        "concurrency": concurrency,
        "n_requests": n_requests,
        "gen_steps": gen_steps,
        "wall_seq_s": wall_seq,
        "wall_cb_s": wall_cb,
        "rps_seq": n_requests / wall_seq,
        "rps_cb": n_requests / wall_cb,
        "speedup": wall_seq / wall_cb,
        "cold_speedup": wall_seq_cold / wall_cb_cold,
        "seq_p50_ms": float(np.percentile(seq_lat, 50) * 1e3),
        "seq_p99_ms": float(np.percentile(seq_lat, 99) * 1e3),
        "cb_p50_ms": float(np.percentile(cb_lat, 50) * 1e3),
        "cb_p99_ms": float(np.percentile(cb_lat, 99) * 1e3),
        "steps": stats.steps,
        "step_faults": stats.faulted_units,
        "max_active": stats.max_active,
        "kv_bytes_step_dense": kv_dense,
        "kv_bytes_step_paged": kv_paged,
        "kv_bytes_step_ratio": kv_paged / kv_dense if kv_dense else 0.0,
        "kv_pages_high_water": stats.kv_pages_high_water,
    }


def _timed_arrivals(sched, prompts, gen_steps, arrivals, deadline_s):
    """Drive the scheduler from a wall-clock arrival schedule: requests are
    submitted at their arrival offsets while ``serve_forever`` runs in a
    worker thread — the open-loop load generator the all-at-t=0 passes
    above can't model."""
    stop = threading.Event()
    worker = threading.Thread(target=sched.serve_forever, args=(stop,), daemon=True)
    worker.start()
    reqs = []
    t0 = time.perf_counter()
    try:
        for t_arr, p in zip(arrivals, prompts):
            delay = t0 + t_arr - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            reqs.append(sched.queue.submit(p, gen_steps, deadline_s=deadline_s))
        for r in reqs:
            if not r.wait(timeout=120):
                raise RuntimeError(f"request {r.rid} never finished")
    finally:
        stop.set()
        worker.join(timeout=10)
    return reqs


def run_burst(
    base_dir: str,
    arch: str = "mixtral-8x22b",
    *,
    concurrency: int = 4,
    n_bursts: int = 3,
    burst_size: int = 12,
    burst_rate: float = 0.0,  # bursts/s; 0 = derive from measured service rate
    prompt_len: int = 8,
    gen_steps: int = 16,
    seed: int = 7,
) -> list[dict]:
    """SLO-aware admission vs FIFO under uniform and Poisson-burst arrivals
    (ISSUE satellite; DESIGN.md §15.2). One server, four timed passes.

    The deadline is self-calibrating: an all-at-once FIFO pass measures
    one request's no-queue service time (the first wave's latency) and
    2x it becomes every request's deadline; the pass also seeds the SLO
    policy's step/prefill estimates. Uniform arrivals at the sustained
    service rate then meet the deadline comfortably, while a Poisson
    burst of ``burst_size`` >> concurrency stacks waves of backlog
    behind the slots — FIFO serves the tail late, SLO sheds it at
    admission and hits the deadline on what it serves.
    """
    app = setup_app(arch, base_dir)
    max_seq = prompt_len + gen_steps + 2
    n_requests = n_bursts * burst_size
    rng = np.random.default_rng(seed)
    prompts = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(300 + i), (prompt_len,), 0, app.cfg.vocab_size))
        for i in range(n_requests)
    ]

    results = []
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len)) as server:
        eng = GenerationEngine(server, max_seq=max_seq)

        # staggered warm-up: group prefills (and slot grafts) compile per
        # admitted-group size, so pay every size 1..concurrency once —
        # otherwise the first timed pass compiles mid-measurement and the
        # calibrated deadline balloons to the compile wall
        warm = ContinuousBatchingScheduler(eng, max_batch=concurrency,
                                           admission=FIFOAdmission())
        warm.warm_compile()
        for g in range(1, concurrency + 1):
            for p in prompts[:g]:
                warm.submit(p, gen_steps)
            warm.run()

        # calibration: all-at-t=0 FIFO pass yields the sustained service
        # rate + the p50 queue latency used as deadline
        cal = ContinuousBatchingScheduler(eng, max_batch=concurrency,
                                          admission=FIFOAdmission())
        t0 = time.perf_counter()
        cal_reqs = [cal.submit(p, gen_steps) for p in prompts]
        cal.run()
        cal_wall = time.perf_counter() - t0
        # the first wave's latency IS one request's service time (no queue
        # wait); a 2x budget over it admits ~two waves of backlog — met
        # comfortably at the sustained rate, hopeless for the back of a
        # burst that stacks three+ waves behind the slots
        base_s = float(np.min([r.latency_s for r in cal_reqs]))
        deadline_s = 2.0 * base_s
        # seed the SLO estimates from the same pass, so the first burst's
        # projections are live numbers, not the class defaults
        step_cal = cal_wall / max(cal.stats.steps, 1)
        prefill_cal = max(base_s - gen_steps * step_cal, step_cal)
        # uniform arrivals at ~75% of the sustained rate: at exactly the
        # service rate (rho = 1) any jitter accumulates into an unbounded
        # queue and "uniform" stops being the well-behaved baseline
        gap = (cal_wall / n_requests) / 0.75

        arrivals_by_mode = {
            "uniform": np.arange(n_requests) * gap,
            "burst": np.repeat(
                np.cumsum(rng.exponential(
                    scale=(1.0 / burst_rate) if burst_rate else burst_size * gap,
                    size=n_bursts)),
                burst_size),
        }
        for mode, arrivals in arrivals_by_mode.items():
            for policy_name, make_policy in (
                    ("fifo", FIFOAdmission),
                    ("slo", lambda: SLOAdmission(step_est_s=step_cal,
                                                 prefill_est_s=prefill_cal))):
                policy = make_policy()
                sched = ContinuousBatchingScheduler(eng, max_batch=concurrency,
                                                    admission=policy)
                reqs = _timed_arrivals(sched, prompts, gen_steps, arrivals, deadline_s)
                served = [r for r in reqs if r.error is None]
                shed = [r for r in reqs if r.shed]
                failed = [r for r in reqs if r.error is not None and not r.shed]
                if failed:
                    raise RuntimeError(f"{mode}/{policy_name}: {failed[0].error}")
                lat = np.array([r.latency_s for r in served])
                hit = [r for r in served
                       if r.deadline_t is None or r.finished_t <= r.deadline_t]
                results.append({
                    "arch": arch,
                    "mode": mode,
                    "policy": policy_name,
                    "n_requests": n_requests,
                    "deadline_ms": deadline_s * 1e3,
                    "served": len(served),
                    "shed": len(shed),
                    "shed_rate": len(shed) / n_requests,
                    "p50_ms": float(np.percentile(lat, 50) * 1e3) if len(lat) else 0.0,
                    "p99_ms": float(np.percentile(lat, 99) * 1e3) if len(lat) else 0.0,
                    "deadline_hit_rate": len(hit) / max(len(served), 1),
                    "stats_shed": sched.stats.shed,
                })
    return results


def main(base_dir: str, *, smoke: bool = False,
         burst_size: int = 0, burst_rate: float = 0.0) -> list[str]:
    kw = dict(n_requests=4, gen_steps=6) if smoke else {}
    r = run(base_dir, **kw)
    bkw = dict(n_bursts=2, burst_size=12, gen_steps=6) if smoke else {}
    if burst_size:
        bkw["burst_size"] = burst_size
    if burst_rate:
        bkw["burst_rate"] = burst_rate
    burst_rows = []
    for b in run_burst(base_dir, **bkw):
        burst_rows.append(csv_row(
            f"rq5_burst/{b['arch']}/{b['mode']}/{b['policy']}",
            b["p99_ms"] * 1e3,
            f"p99={b['p99_ms']:.0f}ms p50={b['p50_ms']:.0f}ms"
            f"|shed={b['shed']}/{b['n_requests']} ({b['shed_rate']:.0%})"
            f"|deadline={b['deadline_ms']:.0f}ms "
            f"hit_rate={b['deadline_hit_rate']:.0%}",
        ))
    return [
        csv_row(
            f"rq5_traffic/{r['arch']}/c{r['concurrency']}",
            r["wall_cb_s"] / r["n_requests"] * 1e6,
            f"throughput={r['rps_cb']:.2f}req/s vs sequential {r['rps_seq']:.2f} "
            f"(sustained speedup {r['speedup']:.2f}x; cold-pass {r['cold_speedup']:.2f}x)"
            f"|lat_p50={r['cb_p50_ms']:.0f}ms p99={r['cb_p99_ms']:.0f}ms "
            f"(seq p50={r['seq_p50_ms']:.0f} p99={r['seq_p99_ms']:.0f})"
            f"|steps={r['steps']}|step_faults={r['step_faults']}"
            f"|kv_bytes_step={r['kv_bytes_step_paged']:.0f}/{r['kv_bytes_step_dense']:.0f} "
            f"({r['kv_bytes_step_ratio']:.0%} of max-shape)"
            f"|outputs=identical",
        ),
        *burst_rows,
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 4 requests x 6 steps at concurrency 4")
    ap.add_argument("--burst-size", type=int, default=0,
                    help="requests per Poisson burst (default: 12 = 3x concurrency)")
    ap.add_argument("--burst-rate", type=float, default=0.0,
                    help="burst arrivals per second (default: derived from "
                         "the measured service rate)")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    args = ap.parse_args()
    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_traffic_")
    print("name,us_per_call,derived")
    for row in main(scratch, smoke=args.smoke,
                    burst_size=args.burst_size, burst_rate=args.burst_rate):
        print(row)
    sys.exit(0)
