"""RQ8 (beyond-paper, DESIGN.md §12): does ONLINE re-tiering — the
restart-free daemon — reduce request-path fault bytes and miss-stall time
after a mid-run workload shift, without changing a single output token?

RQ7 answers the profile→re-tier question with a restart between the
profiling pass and the re-tiered pass; that restart is itself the
cold-start event the paper fights. Here the workload shifts *inside one
serving run* and the only adaptation allowed is the ``RetierDaemon``
ticking between steps.

Workload: two prompt populations drawn from disjoint vocab halves (A =
low rows, B = high rows — disjoint embed row-group working sets), served
as alternating phases **A₁ B₁ A₂ B₂** over one server under the ``stats``
residency budget (50% of tier-1 — the eviction-pressure regime where the
shifted-away phase's units get evicted and refault on return). Two
passes over the SAME request sequence, each a single cold start:

  * **static** — prefetch ON (engine hints only), no daemon: every
    refault after a shift lands on the request path;
  * **online** — same, plus the daemon (trace → decayed merge → replan →
    apply) ticking every few steps: returning-phase units ride the
    prefetch queue as hot-set preloads and the predictor is retrained
    in-run from the merged trace's transitions.

The **post-shift** window (the second A B cycle, after the daemon has
seen both populations once) is where adaptation can pay: request-path
fault bytes and miss-stall seconds are compared there. Greedy outputs
are asserted identical across passes before any number is reported, and
the fault-byte reduction is asserted, not just printed — all with ZERO
restarts (one ``cold_start`` per pass; the online pass adapts in place).

Fault bytes is the scale-free headline (≈30% lower post-shift on the
reduced mixtral); miss-stall *wall seconds* are reported but not
asserted — on the CPU-only miniature the background reader/uploader
contend with the request thread for the same cores, so a demand touch
that overlaps an in-flight preload can wait longer than a cold read
even though its bytes left the request path (see LoaderStats.stalls).

Standalone: ``python -m benchmarks.bench_rq8_online [--smoke] [--json-out F]``
(wired into benchmarks/run.py as the ``rq8`` section and the CI smoke).
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, setup_app, timed_cold_start
from repro.serving import GenerationEngine


def _phase_prompts(app, *, n_per_phase: int, prompt_len: int):
    """Phase-A and phase-B prompt sets from disjoint vocab halves (their
    embed row-groups are disjoint → a real working-set shift)."""
    V = app.cfg.vocab_size
    a = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(300 + i),
                                      (prompt_len,), 0, V // 2))
        for i in range(n_per_phase)
    ]
    b = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(400 + i),
                                      (prompt_len,), V // 2, V))
        for i in range(n_per_phase)
    ]
    return a, b


def _serve_phases(server, phases, gen_steps: int, max_seq: int):
    """Serve the phase sequence on one server (no restart anywhere).
    Returns (all outputs in order, per-phase fault-byte/stall rows)."""
    eng = GenerationEngine(server, max_seq=max_seq)
    outs, rows = [], []
    for prompts in phases:
        ts = server.tiered.stats
        fb0, n0 = ts.request_fault_bytes, len(ts.stalls)
        for p in prompts:
            out, _ = eng.generate(jnp.asarray(p[None, :]), gen_steps)
            outs.append(np.asarray(out[0]))
        rows.append({
            "fault_bytes": ts.request_fault_bytes - fb0,
            "stall_s": float(sum(ts.stalls[n0:])),
        })
    return outs, rows


def run(
    base_dir: str,
    arch: str = "mixtral-8x22b",
    *,
    prompt_len: int = 8,
    gen_steps: int = 8,
    n_per_phase: int = 3,
    retier_interval: int = 6,
    retier_decay: float = 0.5,
    retier_compact_every: int = 2,
) -> dict:
    app = setup_app(arch, base_dir)
    max_seq = prompt_len + gen_steps + 2
    a, b = _phase_prompts(app, n_per_phase=n_per_phase, prompt_len=prompt_len)
    phases = [a, b, a, b]  # shift, shift back, shift again — mid-run, live

    # -- pass 1: static (prefetch on, no daemon) ------------------------------
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                          residency="stats", prefetch=True) as server:
        outs_static, rows_static = _serve_phases(server, phases, gen_steps, max_seq)

    # -- pass 2: online (same + RetierDaemon ticking between steps, with
    # periodic BACKGROUND compaction rewriting the artifact off-thread) -------
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                          residency="stats", prefetch=True,
                          retier_online=True, retier_interval=retier_interval,
                          retier_decay=retier_decay,
                          retier_compact_every=retier_compact_every) as server:
        outs_online, rows_online = _serve_phases(server, phases, gen_steps, max_seq)
        # flush the worker thread so the compaction stats below are final
        # (server.close() would join it anyway; we read stats before that)
        server.retier_daemon.join_compaction(timeout=60.0)
        daemon = server.retier_daemon.stats.to_dict()
        compaction = (server.retier_daemon.last_compaction or {}).get(
            "compaction", {})

    # correctness gate: live adaptation may only move bytes, never tokens
    for got, ref in zip(outs_online, outs_static):
        np.testing.assert_array_equal(got, ref)

    # post-shift = the second A B cycle: the daemon has now profiled both
    # populations, so returning-phase units preload instead of refaulting
    post_static = sum(r["fault_bytes"] for r in rows_static[2:])
    post_online = sum(r["fault_bytes"] for r in rows_online[2:])
    stall_static = sum(r["stall_s"] for r in rows_static[2:])
    stall_online = sum(r["stall_s"] for r in rows_online[2:])
    assert daemon["applies"] > 0, "daemon never applied a plan"
    assert post_online < post_static, (
        f"online re-tiering did not reduce post-shift request-path fault "
        f"bytes: {post_static} -> {post_online}"
    )
    # the §17.3 compaction contract: the periodic rewrite completed on its
    # worker thread without ever failing — and, because live applies never
    # flip tiers (§12.1 rule 2), it moved every frame verbatim (zero
    # recompressions, the §17.1 acceptance) in the trace's co-access order
    if retier_compact_every:
        assert daemon["compactions"] >= 1, "periodic compaction never completed"
        assert daemon["compact_errors"] == 0, "background compaction failed"
        assert compaction.get("recompressed") == 0, (
            f"live compaction recompressed frames: {compaction}")

    return {
        "arch": arch,
        "n_requests": len(phases) * n_per_phase,
        "gen_steps": gen_steps,
        "fault_bytes_post_shift_static": post_static,
        "fault_bytes_post_shift_online": post_online,
        "fault_bytes_reduction": 1.0 - post_online / max(1, post_static),
        "stall_s_post_shift_static": stall_static,
        "stall_s_post_shift_online": stall_online,
        "phase_fault_bytes_static": [r["fault_bytes"] for r in rows_static],
        "phase_fault_bytes_online": [r["fault_bytes"] for r in rows_online],
        "daemon": daemon,
        "compaction": compaction,
        "restarts": 0,
        "outputs_identical": True,
    }


def main(base_dir: str, *, smoke: bool = False, archs=None) -> list[str]:
    archs = archs or (("mixtral-8x22b",) if smoke else ("mixtral-8x22b", "yi-34b"))
    kw = dict(gen_steps=6, n_per_phase=2) if smoke else {}
    rows = []
    for arch in archs:
        r = run(base_dir, arch, **kw)
        d = r["daemon"]
        rows.append(csv_row(
            f"rq8_online/{r['arch']}",
            0.0,
            f"post_shift_fault_bytes {r['fault_bytes_post_shift_static']}->"
            f"{r['fault_bytes_post_shift_online']} "
            f"(-{r['fault_bytes_reduction'] * 100:.0f}%)"
            f"|stall_s {r['stall_s_post_shift_static']:.3f}->"
            f"{r['stall_s_post_shift_online']:.3f}"
            f"|ticks={d['ticks']} applies={d['applies']} "
            f"promoted={d['promoted_units']} demoted={d['demoted_units']}"
            # the §17.3 wall/IO split: compaction wall on the worker thread
            # vs the slowest serving tick (which must NOT contain it)
            f"|compact n={d['compactions']} wall={d['compact_wall_s']:.3f}s "
            f"raw_copied={r['compaction'].get('raw_copied', 0)} "
            f"recompressed={r['compaction'].get('recompressed', 0)} "
            f"layout={r['compaction'].get('layout', {}).get('source', 'n/a')}"
            f"|max_tick={d['max_tick_s'] * 1e3:.1f}ms"
            f"|restarts=0|outputs=identical",
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one arch, 2 prompts x 6 steps per phase")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    ap.add_argument("--json-out", default="",
                    help="also write the CSV rows as a JSON list here")
    args = ap.parse_args()
    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_rq8_")
    print("name,us_per_call,derived")
    rows = main(scratch, smoke=args.smoke)
    for row in rows:
        print(row)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"section": "rq8", "rows": rows}, f, indent=2)
    sys.exit(0)
