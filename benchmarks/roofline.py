"""Roofline table from the dry-run artifacts (assignment deliverable g).

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
emits per-(arch × shape × mesh):

  compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
  memory term     = HLO_bytes / (chips × 819 GB/s)
  collective term = per-device collective bytes / 50 GB/s per link

plus dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), roofline
fraction, and fits-in-HBM (peak device bytes vs 16 GB). FLOPs/bytes are the
loop-aware numbers from repro.utils.hlocost (cost_analysis() counts scan
bodies once; see §Roofline methodology in EXPERIMENTS.md).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16e9

DEFAULT_DIR = "benchmarks/results/dryrun"


def load_records(dirname: str = DEFAULT_DIR, tag: str = "") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def roofline_terms(rec: dict) -> dict:
    chips = rec["num_chips"]
    compute_s = rec["hlo_flops"] / (chips * PEAK_FLOPS)
    memory_s = rec["hlo_bytes"] / (chips * HBM_BW)
    collective_s = rec["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    mem = rec.get("memory", {})
    peak = mem.get("temp_size_in_bytes", 0) + max(
        0, mem.get("argument_size_in_bytes", 0) - mem.get("alias_size_in_bytes", 0)
    )
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": rec["model_flops"],
        "useful_ratio": rec["model_flops"] / rec["hlo_flops"] if rec["hlo_flops"] else 0.0,
        "roofline_fraction": (rec["model_flops"] / (chips * PEAK_FLOPS)) / bound if bound else 0.0,
        "peak_device_bytes": peak,
        "fits": peak <= HBM_BYTES,
        "tag": rec.get("tag", ""),
    }


def table(dirname: str = DEFAULT_DIR, tag: str = "") -> list[dict]:
    out = []
    for rec in load_records(dirname, tag):
        if rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                        "dominant": "SKIPPED", "reason": rec.get("reason", "")})
            continue
        out.append(roofline_terms(rec))
    return out


def format_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | dominant "
           "| MF/HF | roofline frac | peak GiB | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["dominant"] == "SKIPPED":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | skipped | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['peak_device_bytes']/2**30:.1f} "
            f"| {'✓' if r['fits'] else '✗'} |"
        )
    return "\n".join(lines)


def main(dirname: str = DEFAULT_DIR) -> list[str]:
    rows = table(dirname)
    out = []
    for r in rows:
        if r["dominant"] == "SKIPPED":
            out.append(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,skipped")
            continue
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},{r['bound_s']*1e6:.1f},"
            f"dominant={r['dominant']}|frac={r['roofline_fraction']:.2f}"
            f"|useful={r['useful_ratio']:.2f}|fits={r['fits']}"
        )
    return out


if __name__ == "__main__":
    print(format_markdown(table()))
