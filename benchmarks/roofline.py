"""Roofline table from the dry-run artifacts (assignment deliverable g)
plus the serving-side KV-bytes/step gate (DESIGN.md §16.2).

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
emits per-(arch × shape × mesh):

  compute term    = HLO_FLOPs / (chips × peak_flops)
  memory term     = HLO_bytes / (chips × hbm_bw)
  collective term = per-device collective bytes / ici_bw per link

plus dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), roofline
fraction, and fits-in-HBM (peak device bytes vs hbm_bytes). FLOPs/bytes are
the loop-aware numbers from repro.utils.hlocost (cost_analysis() counts scan
bodies once; see §Roofline methodology in EXPERIMENTS.md).

Peak numbers come from a named ``Machine`` (``--machine``, default
``tpu-v5e``) so the table is honest about WHICH datasheet it divides by —
off-TPU runs can pass their own machine instead of silently inheriting
v5e ceilings.

``decode_kv_bytes`` converts the scheduler's paged-KV accounting
(``SchedulerStats.kv_tokens_dense`` / ``kv_tokens_paged``) into the
achieved-vs-max-shape KV bytes/step for the masked decode step — the gate
rq5's traffic benchmark reports (reduced bytes/step with outputs
unchanged).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass


@dataclass(frozen=True)
class Machine:
    """Peak datasheet numbers a roofline divides by. ``provenance`` says
    where each ceiling comes from — a roofline against undocumented peaks
    is a ratio against nothing."""

    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bw: float      # HBM bytes/s per chip
    ici_bw: float      # interconnect bytes/s per link
    hbm_bytes: float   # HBM capacity per chip
    provenance: str


MACHINES: dict[str, Machine] = {
    m.name: m
    for m in [
        Machine(
            name="tpu-v5e",
            peak_flops=197e12,
            hbm_bw=819e9,
            ici_bw=50e9,
            hbm_bytes=16e9,
            provenance=(
                "TPU v5e datasheet (cloud.google.com/tpu/docs/v5e): "
                "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI, 16 GB HBM"
            ),
        ),
        Machine(
            name="tpu-v4",
            peak_flops=275e12,
            hbm_bw=1228e9,
            ici_bw=50e9,
            hbm_bytes=32e9,
            provenance=(
                "TPU v4 datasheet (cloud.google.com/tpu/docs/v4): "
                "275 TFLOP/s bf16, 1228 GB/s HBM, 50 GB/s/link ICI, 32 GB HBM"
            ),
        ),
        Machine(
            name="cpu-interpret",
            peak_flops=1e12,
            hbm_bw=50e9,
            ici_bw=10e9,
            hbm_bytes=64e9,
            provenance=(
                "order-of-magnitude CI host (interpret-mode runs): terms are "
                "comparable to each other, not to hardware"
            ),
        ),
    ]
}

DEFAULT_MACHINE = MACHINES["tpu-v5e"]

# legacy aliases (repro.utils.hlo mirrors these): the pre-Machine module
# constants, kept pointing at the default machine so old imports resolve
PEAK_FLOPS = DEFAULT_MACHINE.peak_flops
HBM_BW = DEFAULT_MACHINE.hbm_bw
ICI_BW = DEFAULT_MACHINE.ici_bw
HBM_BYTES = DEFAULT_MACHINE.hbm_bytes

DEFAULT_DIR = "benchmarks/results/dryrun"


def load_records(dirname: str = DEFAULT_DIR, tag: str = "") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def decode_kv_bytes(
    kv_tokens: int,
    *,
    num_layers: int,
    num_kv_heads: int,
    head_dim: int,
    dtype_bytes: int = 2,
) -> int:
    """KV-cache bytes a decode pass streams for ``kv_tokens`` cache
    positions: K and V, every layer, every kv head. Feed it the
    scheduler's ``kv_tokens_dense`` (max-shape masked decode) and
    ``kv_tokens_paged`` (occupied pages only) to get the §16.2 gate's
    achieved-vs-max-shape bytes/step."""
    return int(kv_tokens) * 2 * num_layers * num_kv_heads * head_dim * dtype_bytes


def roofline_terms(rec: dict, machine: Machine = DEFAULT_MACHINE) -> dict:
    chips = rec["num_chips"]
    compute_s = rec["hlo_flops"] / (chips * machine.peak_flops)
    memory_s = rec["hlo_bytes"] / (chips * machine.hbm_bw)
    collective_s = rec["collective_bytes"] / machine.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    mem = rec.get("memory", {})
    peak = mem.get("temp_size_in_bytes", 0) + max(
        0, mem.get("argument_size_in_bytes", 0) - mem.get("alias_size_in_bytes", 0)
    )
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "machine": machine.name,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": rec["model_flops"],
        "useful_ratio": rec["model_flops"] / rec["hlo_flops"] if rec["hlo_flops"] else 0.0,
        "roofline_fraction": (rec["model_flops"] / (chips * machine.peak_flops)) / bound if bound else 0.0,
        "peak_device_bytes": peak,
        "fits": peak <= machine.hbm_bytes,
        "tag": rec.get("tag", ""),
    }


def table(dirname: str = DEFAULT_DIR, tag: str = "",
          machine: Machine = DEFAULT_MACHINE) -> list[dict]:
    out = []
    for rec in load_records(dirname, tag):
        if rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                        "dominant": "SKIPPED", "reason": rec.get("reason", "")})
            continue
        out.append(roofline_terms(rec, machine))
    return out


def format_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | dominant "
           "| MF/HF | roofline frac | peak GiB | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["dominant"] == "SKIPPED":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | skipped | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['peak_device_bytes']/2**30:.1f} "
            f"| {'✓' if r['fits'] else '✗'} |"
        )
    return "\n".join(lines)


def main(dirname: str = DEFAULT_DIR, machine: Machine = DEFAULT_MACHINE) -> list[str]:
    rows = table(dirname, machine=machine)
    out = []
    for r in rows:
        if r["dominant"] == "SKIPPED":
            out.append(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},0.0,skipped")
            continue
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},{r['bound_s']*1e6:.1f},"
            f"dominant={r['dominant']}|frac={r['roofline_fraction']:.2f}"
            f"|useful={r['useful_ratio']:.2f}|fits={r['fits']}"
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--dir", default=DEFAULT_DIR)
    ap.add_argument("--machine", default=DEFAULT_MACHINE.name,
                    choices=sorted(MACHINES),
                    help="peak-numbers datasheet to divide by")
    args = ap.parse_args()
    m = MACHINES[args.machine]
    print(f"machine: {m.name} — {m.provenance}")
    print(format_markdown(table(args.dir, machine=m)))
