"""RQ7 (beyond-paper, DESIGN.md §11): does one profile → re-tier →
re-serve cycle reduce request-path cold-fault bytes and raise the
prefetch hit rate, without changing a single output token?

Three passes over the SAME request set per architecture, each on a fresh
cold start, all under the ``stats`` residency preset (50%-of-tier-1
device budget — the memory-pressure regime where re-tiering matters: the
reduced configs are small enough that an unbudgeted request warms the
whole tier-1 pool in one pass, leaving nothing to predict):

  * **profile** — the original one-shot-analyzed artifact, prefetch OFF
    (so the trace sees every fault undisturbed), ``AccessTrace`` attached;
  * **retier** — the artifact replanned from that trace
    (``replan_from_trace`` under a promotion budget of half the observed
    fault bytes) and rewritten out-of-place (``retier_artifact``), plain
    prefetch ON;
  * **retier+pred** — same re-tiered artifact with the trace-trained
    ``TransitionPredictor`` armed (evicted units are re-pulled *ahead* of
    their refault, not at it).

Greedy outputs are asserted identical across all passes before any number
is reported; the cold-fault-bytes reduction and the hit-rate increase over
the (prefetch-less) profile pass are asserted, not just printed.

Standalone: ``python -m benchmarks.bench_rq7_retier [--smoke] [--json-out F]``
(wired into benchmarks/run.py as the ``rq7`` section and the CI smoke).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, setup_app, timed_cold_start
from repro.core import AccessTrace, TransitionPredictor, replan_from_trace, retier_artifact
from repro.serving import GenerationEngine, cold_start


def _workload(server, prompts, gen_steps: int, max_seq: int):
    """Serve the fixed request set sequentially; returns (outputs, stats)."""
    eng = GenerationEngine(server, max_seq=max_seq)
    outs = []
    for p in prompts:
        out, _ = eng.generate(jnp.asarray(p[None, :]), gen_steps)
        outs.append(np.asarray(out[0]))
    return outs, server.tiered.stats


def run(
    base_dir: str,
    arch: str = "mixtral-8x22b",
    *,
    prompt_len: int = 8,
    gen_steps: int = 10,
    n_requests: int = 3,
    promote_budget_frac: float = 0.5,
) -> dict:
    app = setup_app(arch, base_dir)
    max_seq = prompt_len + gen_steps + 2
    prompts = [
        np.asarray(jax.random.randint(jax.random.PRNGKey(200 + i), (prompt_len,), 0, app.cfg.vocab_size))
        for i in range(n_requests)
    ]

    # -- pass 1: profile (prefetch off so the trace sees every fault) ---------
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                          residency="stats", prefetch=False, trace=True) as server:
        outs_profile, stats = _workload(server, prompts, gen_steps, max_seq)
        fault_bytes_before = stats.request_fault_bytes
        faults_before = stats.misses
        hit_before = stats.prefetch_hit_rate
        trace = server.tiered.trace

    # round-trip through JSON — the exact path the launcher's
    # --profile-out / --retier-from flags take
    trace = AccessTrace.from_json(trace.to_json())

    # -- re-tier under a promotion budget: promote the hottest half of the
    # observed fault bytes, leaving cold traffic for the predictor to hide
    budget = max(1, int(fault_bytes_before * promote_budget_frac))
    new_plan, report = replan_from_trace(app.result.plan, trace, app.result.reach,
                                         max_promote_bytes=budget)
    retier_dir = app.outdir.rstrip("/") + "-retier"
    retier_artifact(app.outdir, new_plan, out_dir=retier_dir, report=report)
    new_result = dataclasses.replace(app.result, plan=new_plan)

    # -- pass 2: re-tiered artifact, plain prefetch --------------------------
    with cold_start(app.model, retier_dir, new_result, mode="after2",
                    warm_shapes=((1, prompt_len),), residency="stats",
                    prefetch=True) as server:
        outs_retier, stats = _workload(server, prompts, gen_steps, max_seq)
        fault_bytes_retier = stats.request_fault_bytes
        hit_retier = stats.prefetch_hit_rate

    # -- pass 3: re-tiered artifact + trace-trained predictor ----------------
    predictor = TransitionPredictor.from_trace(trace)
    with cold_start(app.model, retier_dir, new_result, mode="after2",
                    warm_shapes=((1, prompt_len),), residency="stats",
                    prefetch=True, predictor=predictor) as server:
        outs_pred, stats = _workload(server, prompts, gen_steps, max_seq)
        fault_bytes_pred = stats.request_fault_bytes
        faults_pred = stats.misses
        hit_pred = stats.prefetch_hit_rate
        predicted = server.prefetcher.stats.predicted

    # correctness gate: re-tiering may only move bytes, never tokens
    for outs in (outs_retier, outs_pred):
        for got, ref in zip(outs, outs_profile):
            np.testing.assert_array_equal(got, ref)
    # the acceptance contract: fewer request-path cold-fault bytes, and a
    # hit rate where the profiling pass (prefetch off) had none
    assert fault_bytes_pred < fault_bytes_before, (
        f"re-tier did not reduce cold-fault bytes: "
        f"{fault_bytes_before} -> {fault_bytes_pred}"
    )
    assert hit_pred > hit_before, (
        f"predictive prefetch hit rate did not increase: "
        f"{hit_before} -> {hit_pred}"
    )

    return {
        "arch": arch,
        "n_requests": n_requests,
        "gen_steps": gen_steps,
        "fault_bytes_profile": fault_bytes_before,
        "fault_bytes_retier": fault_bytes_retier,
        "fault_bytes_pred": fault_bytes_pred,
        "fault_bytes_reduction": 1.0 - fault_bytes_pred / max(1, fault_bytes_before),
        "faults_profile": faults_before,
        "faults_pred": faults_pred,
        "hit_rate_profile": hit_before,
        "hit_rate_retier": hit_retier,
        "hit_rate_pred": hit_pred,
        "predicted_loads": predicted,
        "promoted_resident": len(report.promoted_resident),
        "demoted_resident": len(report.demoted_resident),
        "promoted_bytes": report.promoted_bytes,
        "outputs_identical": True,
    }


def main(base_dir: str, *, smoke: bool = False, archs=None) -> list[str]:
    archs = archs or (("mixtral-8x22b",) if smoke else ("mixtral-8x22b", "yi-34b"))
    kw = dict(gen_steps=8, n_requests=2) if smoke else {}
    rows = []
    for arch in archs:
        r = run(base_dir, arch, **kw)
        rows.append(csv_row(
            f"rq7_retier/{r['arch']}",
            0.0,
            f"fault_bytes {r['fault_bytes_profile']}->{r['fault_bytes_pred']} "
            f"(-{r['fault_bytes_reduction'] * 100:.0f}%)"
            f"|hit_rate {r['hit_rate_profile']:.2f}->{r['hit_rate_pred']:.2f} "
            f"(plain prefetch {r['hit_rate_retier']:.2f})"
            f"|promoted={r['promoted_resident']} demoted={r['demoted_resident']}"
            f"|predicted_loads={r['predicted_loads']}"
            f"|outputs=identical",
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one arch, 2 requests x 8 steps")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    ap.add_argument("--json-out", default="",
                    help="also write the CSV rows as a JSON list here")
    args = ap.parse_args()
    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_rq7_")
    print("name,us_per_call,derived")
    rows = main(scratch, smoke=args.smoke)
    for row in rows:
        print(row)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"section": "rq7", "rows": rows}, f, indent=2)
    sys.exit(0)
