"""RQ1 (paper Fig. 4 / §5.2): how much does FaaSLight shrink the artifact?

Size  := deployment package bytes (before / after1 / after2 cold-resident)
FC    := number of eager-loaded leaves (the paper's function count)
LoC   := eager-loaded parameter count (the paper's executable-line count)
"""

from __future__ import annotations

from benchmarks.common import BENCH_ARCHS, artifact_bytes, csv_row, setup_app


def run(base_dir: str, archs=BENCH_ARCHS) -> list[dict]:
    rows = []
    for arch in archs:
        app = setup_app(arch, base_dir)
        plan = app.result.plan
        before = artifact_bytes(app, "before")
        after1 = artifact_bytes(app, "after1")
        after2_pkg = artifact_bytes(app, "after2")
        cold = plan.cold_resident_bytes
        n_leaves = len(plan.decisions)
        n_tier0 = sum(1 for d in plan.decisions.values() if d.tier == 0)
        rows.append(
            {
                "arch": arch,
                "before_bytes": before,
                "after1_bytes": after1,
                "after2_pkg_bytes": after2_pkg,
                "cold_resident_bytes": cold,
                "size_after1_pct": 100.0 * after1 / before,
                "size_after2_pct": 100.0 * after2_pkg / before,
                "cold_resident_pct": 100.0 * cold / before,
                "fc_before": n_leaves,
                "fc_after2": n_tier0,
                "fc_reduction_pct": 100.0 * (1 - n_tier0 / n_leaves),
                "tier0_fraction": plan.tier0_fraction,
            }
        )
    return rows


def main(base_dir: str) -> list[str]:
    out = []
    rows = run(base_dir)
    for r in rows:
        out.append(csv_row(
            f"rq1_size/{r['arch']}",
            0.0,
            f"after1={r['size_after1_pct']:.1f}%|after2_pkg={r['size_after2_pct']:.1f}%"
            f"|cold_resident={r['cold_resident_pct']:.1f}%|fc_cut={r['fc_reduction_pct']:.1f}%",
        ))
    avg1 = sum(r["size_after1_pct"] for r in rows) / len(rows)
    avg2 = sum(r["cold_resident_pct"] for r in rows) / len(rows)
    out.append(csv_row("rq1_size/mean", 0.0, f"after1={avg1:.1f}%|cold_resident={avg2:.1f}%"))
    return out
