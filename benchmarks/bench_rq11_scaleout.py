"""RQ11 (beyond-paper, DESIGN.md §15): scale-out serving on a simulated
multi-device host — mesh-sharded tiered load and warm snapshot/restore.

Three questions, one reduced MoE app:

  * **shard-load** — tier-0 bundle upload + tier-1 full fault-in onto a
    debug mesh with the §6 sharding rules vs. the same bytes replicated
    to every device (``put=`` override with an empty PartitionSpec).
    Sharding moves 1/shards of the bytes per device, so the wall-clock
    and the per-device residency charge both shrink.
  * **restore** — a replica joining from a warm server snapshot
    (``cold_start(restore_from=...)``) vs. an identical replica joining
    cold and re-faulting on the request path. First-request TTFT and
    request-path fault bytes are compared; restore must cut fault
    traffic by >= 2x.
  * **parity** — the §15.1 contract: greedy outputs are asserted
    identical between the eager sharded baseline (mode="before" on the
    mesh) and the tiered sharded server, and between the cold and the
    restored replica. (Cross-geometry tokens are only tolerance-close —
    GSPMD reorders bf16 partial sums — so parity is asserted per
    geometry, and cross-geometry on the *loaded bytes*.)

The mesh wants 8 simulated devices: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI scale-out
smoke job does; standalone ``python -m benchmarks.bench_rq11_scaleout``
sets it before jax initializes). On fewer devices it degrades to a
1xN mesh and says so.

Wired into ``benchmarks/run.py`` as the ``rq11`` section and the
``rq11_smoke`` entry of ``--smoke``.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

# NOTE: no jax (or jax-importing repro/benchmarks module) at import time —
# __main__ must be able to force the 8-device host platform first.


def _mesh_or_fallback():
    import jax

    from repro.launch.mesh import make_debug_mesh

    n = jax.device_count()
    if n >= 8:
        return make_debug_mesh(2, 4), "2x4"
    return make_debug_mesh(1, n), f"1x{n}(degraded)"


def _serve(server, prompts, gen_steps, max_seq, *, canary=None):
    """Sequential greedy passes; returns (outputs, per-request TTFT s,
    request-path fault bytes consumed). TTFT is time-to-first-token —
    the first token is the prefill's argmax, so its cost is the request's
    fault stall plus the prefill compute. ``canary`` is an optional
    warmup prompt served (and discarded) first: the pre-admission canary
    request every replica pays identically, so first-call jit dispatch
    compiles don't drown the reduced-scale fault signal."""
    import jax.numpy as jnp
    import numpy as np

    from repro.serving import GenerationEngine

    eng = GenerationEngine(server, max_seq=max_seq)
    if canary is not None:
        eng.generate(jnp.asarray(canary[None, :]), gen_steps)
    outs, ttfts = [], []
    fault0 = server.tiered.stats.request_fault_bytes if server.tiered else 0
    for p in prompts:
        out, st = eng.generate(jnp.asarray(p[None, :]), gen_steps)
        ttfts.append(st.fault_s + st.prefill_s)
        outs.append(np.asarray(out[0]))
    fault1 = server.tiered.stats.request_fault_bytes if server.tiered else 0
    return outs, ttfts, fault1 - fault0


def run(
    base_dir: str,
    arch: str = "mixtral-8x22b",
    *,
    n_requests: int = 4,
    prompt_len: int = 6,
    gen_steps: int = 6,
) -> dict:
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks.common import setup_app, timed_cold_start
    from repro.utils.tree import flatten_with_paths

    app = setup_app(arch, base_dir)
    mesh, geometry = _mesh_or_fallback()
    max_seq = prompt_len + gen_steps + 2
    prompts = [
        np.asarray(jax.random.randint(
            jax.random.PRNGKey(100 + i), (prompt_len,), 0, app.cfg.vocab_size))
        for i in range(n_requests)
    ]

    # -- (a) sharded vs replicated tiered load over the same mesh -------------
    replicate = lambda host: jax.device_put(host, NamedSharding(mesh, P()))
    loads = {}
    for label, kw in (("replicated", {"put": replicate}), ("sharded", {"mesh": mesh})):
        best = None
        for _ in range(2):  # best-of-2: cold-start wall is noisy on CI hosts
            with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                                  compile_warm=False, **kw) as server:
                t0 = time.perf_counter()
                server.tiered.ensure_all()
                fault_wall = time.perf_counter() - t0
                rec = {
                    "upload_s": server.report.upload_s,
                    "fault_wall_s": fault_wall,
                    "load_s": server.report.upload_s + fault_wall,
                    "charged": server.tiered.residency.charged_bytes(),
                    "divs": dict(server.tiered._shard_div),
                    "tree": {p: np.asarray(v)
                             for p, v in flatten_with_paths(server.tiered.tree())},
                }
                if best is None or rec["load_s"] < best["load_s"]:
                    best = rec
        loads[label] = best
    n_sharded = sum(1 for d in loads["sharded"]["divs"].values() if d > 1)
    if geometry == "2x4":
        assert n_sharded > 0, loads["sharded"]["divs"]
        assert loads["sharded"]["charged"] < loads["replicated"]["charged"]
    # cross-geometry/\-sharding load parity: every resolved leaf bit-identical
    for p, v in loads["replicated"]["tree"].items():
        np.testing.assert_array_equal(v, loads["sharded"]["tree"][p], err_msg=p)

    # parity within the sharded geometry: eager baseline == tiered serving
    with timed_cold_start(app, "before", warm_shape=(1, prompt_len), mesh=mesh) as server:
        eager_out, _, _ = _serve(server, prompts, gen_steps, max_seq)
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len), mesh=mesh) as server:
        tiered_out, _, _ = _serve(server, prompts, gen_steps, max_seq)
    for a, b in zip(eager_out, tiered_out):
        np.testing.assert_array_equal(a, b)

    # -- (b) warm snapshot/restore vs cold re-faulting join --------------------
    # both warm shapes: prefill at prompt_len AND the max_seq decode cache,
    # so neither replica jit-compiles on the request path — TTFT compares
    # fault traffic, not shared one-time compiles
    ttft_warm = dict(warm_shapes=((1, prompt_len), (1, max_seq)))
    # constant-token canary: triggers every jit dispatch compile while
    # routing through the fewest experts/vocab rows, so the cold replica
    # still pays the stream's faults on the measured requests
    canary = np.zeros((prompt_len,), np.int32)
    with timed_cold_start(app, "after2", **ttft_warm) as server:
        donor_out, _, _ = _serve(server, prompts, gen_steps, max_seq, canary=canary)
        snap = server.snapshot()

    with timed_cold_start(app, "after2", **ttft_warm) as server:
        cold_out, cold_walls, cold_fault = _serve(
            server, prompts, gen_steps, max_seq, canary=canary)
    with timed_cold_start(app, "after2", restore_from=snap, **ttft_warm) as server:
        restore_report = server.restore_report
        warm_out, warm_walls, warm_fault = _serve(
            server, prompts, gen_steps, max_seq, canary=canary)

    # -- (c) parity: cold, restored, and donor replicas serve identically -----
    for a, b, c in zip(donor_out, cold_out, warm_out):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    assert restore_report["restored"] > 0
    # the restored replica must not re-pay the donor's request-path faults
    assert warm_fault * 2 <= max(cold_fault, 1), (warm_fault, cold_fault)

    return {
        "arch": arch,
        "geometry": geometry,
        "n_devices": jax.device_count(),
        "sharded_leaves": n_sharded,
        "load_repl_s": loads["replicated"]["load_s"],
        "load_shard_s": loads["sharded"]["load_s"],
        "load_speedup": loads["replicated"]["load_s"] / max(loads["sharded"]["load_s"], 1e-9),
        "charged_repl": loads["replicated"]["charged"],
        "charged_shard": loads["sharded"]["charged"],
        "ttft_cold_ms": cold_walls[0] * 1e3,
        "ttft_restored_ms": warm_walls[0] * 1e3,
        "ttft_speedup": cold_walls[0] / max(warm_walls[0], 1e-9),
        "fault_cold_bytes": cold_fault,
        "fault_restored_bytes": warm_fault,
        "restored_units": restore_report["restored"],
    }


def main(base_dir: str, *, smoke: bool = False) -> list[str]:
    from benchmarks.common import csv_row

    kw = dict(n_requests=3, gen_steps=4) if smoke else {}
    r = run(base_dir, **kw)
    return [
        csv_row(
            f"rq11_shardload/{r['arch']}/{r['geometry']}",
            r["load_shard_s"] * 1e6,
            f"sharded_load={r['load_shard_s']*1e3:.0f}ms vs replicated "
            f"{r['load_repl_s']*1e3:.0f}ms ({r['load_speedup']:.2f}x) "
            f"on {r['n_devices']}dev|sharded_leaves={r['sharded_leaves']}"
            f"|charged {r['charged_shard']}B vs {r['charged_repl']}B replicated",
        ),
        csv_row(
            f"rq11_restore/{r['arch']}",
            r["ttft_restored_ms"] * 1e3,
            f"ttft_restored={r['ttft_restored_ms']:.0f}ms vs cold-join "
            f"{r['ttft_cold_ms']:.0f}ms ({r['ttft_speedup']:.2f}x)"
            f"|request_faults {r['fault_restored_bytes']}B vs {r['fault_cold_bytes']}B"
            f"|restored_units={r['restored_units']}|outputs=identical",
        ),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 3 requests x 4 steps")
    ap.add_argument("--devices", type=int, default=8,
                    help="simulated host device count (default 8)")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    args = ap.parse_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_scaleout_")
    print("name,us_per_call,derived")
    for row in main(scratch, smoke=args.smoke):
        print(row)
    sys.exit(0)
