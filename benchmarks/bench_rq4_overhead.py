"""RQ4 (paper §5.5): the on-demand loading overhead, and its one-time
nature. Measures per-fault latency (fetch+decompress+upload), total fault
cost of a fully-cold first request, and confirms the second request over
the same routes pays zero.

Beyond-paper (DESIGN.md §8.2): the same fully-cold first request is
repeated on a prefetch-enabled server; the engine's hints overlap
fetch+decompress with compute, so part of the fault cost moves off the
request path (reported as the prefetch row)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, request_tokens, setup_app, timed_cold_start
from repro.core import DeploymentProfile
from repro.serving import GenerationEngine


def run(base_dir: str, arch: str = "mixtral-8x22b") -> dict:
    from repro.configs import get_reduced

    cfg = get_reduced(arch)
    profile = DeploymentProfile(  # strict: everything tier-1 cold
        resident_experts=0, hot_vocab_fraction=0.0,
        min_tier1_bytes=1 << 12, vocab_row_group=max(64, cfg.vocab_size // 16),
    )
    app = setup_app(arch, base_dir, profile=profile, stats=False)
    server = timed_cold_start(app, "after2")
    try:
        eng = GenerationEngine(server, max_seq=32)
        toks = request_tokens(app)
        _, st1 = eng.generate(toks, 6)
        _, st2 = eng.generate(toks, 6)
        ev = server.tiered.stats.events
        fetch = np.array([e.fetch_s for e in ev])
        upload = np.array([e.upload_s for e in ev])
    finally:
        server.close()

    # same fully-cold request, with the engine's hints driving the prefetcher
    server_pf = timed_cold_start(app, "after2", prefetch=True)
    try:
        eng_pf = GenerationEngine(server_pf, max_seq=32)
        _, st_pf = eng_pf.generate(toks, 6)
        ts_pf = server_pf.tiered.stats
    finally:
        server_pf.close()
    return {
        "arch": arch,
        "faults_first": st1.faulted_units,
        "fault_bytes_first": st1.faulted_bytes,
        "fault_s_first": st1.fault_s,
        "retries_first": st1.prefill_retries + st1.decode_retries,
        "faults_second": st2.faulted_units,
        "fault_s_second": st2.fault_s,
        "mean_fetch_ms": float(fetch.mean() * 1e3) if len(fetch) else 0.0,
        "mean_upload_ms": float(upload.mean() * 1e3) if len(upload) else 0.0,
        "per_fault_ms": float((fetch + upload).mean() * 1e3) if len(ev) else 0.0,
        "pf_faults_first": st_pf.faulted_units,
        "pf_fault_s_first": st_pf.fault_s,
        "pf_hits_first": st_pf.prefetch_hits,
        "pf_hit_rate": ts_pf.prefetch_hit_rate,
        "pf_stall_p99_ms": ts_pf.stall_percentile(99) * 1e3,
    }


def main(base_dir: str) -> list[str]:
    r = run(base_dir)
    return [
        csv_row(
            f"rq4_overhead/{r['arch']}",
            r["per_fault_ms"] * 1e3,
            f"first_req: {r['faults_first']} faults "
            f"({r['fault_bytes_first']/2**20:.2f}MiB, {r['fault_s_first']*1e3:.1f}ms, "
            f"{r['retries_first']} retries)|second_req: {r['faults_second']} faults"
            f"|per_fault={r['per_fault_ms']:.2f}ms "
            f"(fetch {r['mean_fetch_ms']:.2f} + upload {r['mean_upload_ms']:.2f})",
        ),
        csv_row(
            f"rq4_overhead/{r['arch']}/prefetch",
            r["pf_fault_s_first"] * 1e6,
            f"first_req: {r['pf_faults_first']} sync faults "
            f"({r['pf_fault_s_first']*1e3:.1f}ms on-path)"
            f"|hidden_by_prefetch={r['pf_hits_first']}"
            f"|hit_rate={r['pf_hit_rate']:.2f}|stall_p99={r['pf_stall_p99_ms']:.2f}ms",
        ),
    ]
