"""RQ6 (paper Tables 4-7): generality.

The paper varies language (Python/JS) and platform (AWS/GCF); the framework
analogue varies *model family* (dense / MoE / MLA / hybrid / SSM / enc-dec /
VLM) and *deployment profile* (text-only vs multimodal serving) — the
technique must produce a valid, faster cold start everywhere without
per-family engineering."""

from __future__ import annotations

import jax

from benchmarks.common import csv_row, setup_app, timed_cold_start
from repro.configs import ARCH_IDS

FAMILIES = {
    "recurrentgemma-9b": "hybrid",
    "mistral-large-123b": "dense",
    "gemma3-27b": "dense",
    "phi3-medium-14b": "dense",
    "yi-34b": "dense",
    "mixtral-8x22b": "moe",
    "deepseek-v2-lite-16b": "moe+mla",
    "whisper-base": "enc-dec",
    "xlstm-125m": "ssm",
    "llama-3.2-vision-90b": "vlm",
}


def run(base_dir: str, archs=tuple(ARCH_IDS)) -> list[dict]:
    rows = []
    for arch in archs:
        app = setup_app(arch, base_dir)
        jax.clear_caches()
        s_b = timed_cold_start(app, "before", compile_warm=False)
        jax.clear_caches()
        s_t = timed_cold_start(app, "after2", compile_warm=False)
        plan = app.result.plan
        rows.append(
            {
                "arch": arch,
                "family": FAMILIES[arch],
                "cold_before_ms": s_b.report.total_s * 1e3,
                "cold_after2_ms": s_t.report.total_s * 1e3,
                "reduction_pct": 100.0 * (1 - s_t.report.total_s / max(s_b.report.total_s, 1e-9)),
                "bytes_cut_pct": 100.0 * (1 - plan.cold_resident_bytes / plan.total_bytes),
            }
        )
    return rows


def main(base_dir: str) -> list[str]:
    out = []
    rows = run(base_dir)
    for r in rows:
        out.append(csv_row(
            f"rq6_generality/{r['arch']}",
            r["cold_after2_ms"] * 1e3,
            f"family={r['family']}|before={r['cold_before_ms']:.0f}ms"
            f"|after2={r['cold_after2_ms']:.0f}ms|cut={r['reduction_pct']:.1f}%"
            f"|bytes_cut={r['bytes_cut_pct']:.1f}%",
        ))
    pos = sum(1 for r in rows if r["bytes_cut_pct"] > 0)
    out.append(csv_row("rq6_generality/summary", 0.0,
                       f"{pos}/{len(rows)} families improved"))
    return out
