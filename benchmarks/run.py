"""Benchmark aggregator: one section per paper table/figure + the roofline.

``PYTHONPATH=src python -m benchmarks.run [--fast] [--runs N] [--out DIR]``

Prints ``name,us_per_call,derived`` CSV rows (assignment contract); with
``--json-out FILE`` the same rows are also written as a JSON document
(section → rows) for machine consumers (CI uploads this as a build
artifact). The RQ benchmarks measure the reduced configs live on CPU;
the roofline section reads the dry-run artifacts if present.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--runs", type=int, default=5, help="cold-start repetitions (paper: 20)")
    ap.add_argument("--fast", action="store_true", help="3 runs, fewer archs")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: rq2 (one arch, 2 runs, no warm-set compile) "
                         "+ the rq7 profile→re-tier cycle + the rq8 online "
                         "re-tier shift + the rq9 multi-model zoo + the rq10 "
                         "fleet federation + the rq11 scale-out mesh/snapshot "
                         "(~7 min)")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    ap.add_argument("--only", default="",
                    help="comma list: rq1,rq2,rq3,rq4,rq5,traffic,rq6,rq7,rq8,rq9,rq10,rq11,roofline")
    ap.add_argument("--json-out", default="",
                    help="also write all rows as JSON {section: [rows]} here")
    args = ap.parse_args(argv)
    n_runs = 3 if args.fast else args.runs

    from benchmarks import (
        bench_rq1_size,
        bench_rq2_cold,
        bench_rq3_warm,
        bench_rq4_overhead,
        bench_rq5_comparison,
        bench_rq5_traffic,
        bench_rq6_generality,
        bench_rq7_retier,
        bench_rq8_online,
        bench_rq9_zoo,
        bench_rq10_fleet,
        bench_rq11_scaleout,
        roofline,
    )

    only = set(filter(None, args.only.split(",")))
    want = lambda k: not only or k in only

    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_bench_")
    os.makedirs(scratch, exist_ok=True)
    print(f"# FaaSLight-JAX benchmarks (artifacts: {scratch}; runs={n_runs})")
    print("name,us_per_call,derived")

    by_section: dict[str, list[str]] = {}

    def _flush_json() -> None:
        if args.json_out:
            tmp = args.json_out + ".partial"
            with open(tmp, "w") as f:
                json.dump(by_section, f, indent=2)
            os.replace(tmp, args.json_out)

    sections = []
    if args.smoke:
        smoke = [
            ("rq2", lambda: bench_rq2_cold.main(
                scratch, n_runs=2, archs=("mixtral-8x22b",), compile_warm=False)),
            ("rq7", lambda: bench_rq7_retier.main(scratch, smoke=True)),
            ("rq8", lambda: bench_rq8_online.main(scratch, smoke=True)),
            ("rq9", lambda: bench_rq9_zoo.main(scratch, smoke=True)),
            ("rq10", lambda: bench_rq10_fleet.main(scratch, smoke=True)),
            ("rq11", lambda: bench_rq11_scaleout.main(scratch, smoke=True)),
        ]
        # --only filters smoke sections too (CI's dedicated scale-out job
        # runs `--smoke --only rq11` under an 8-device host platform)
        sections = [(f"{k}_smoke", fn) for k, fn in smoke if want(k)]
    else:
        if want("rq1"):
            sections.append(("rq1", lambda: bench_rq1_size.main(scratch)))
        if want("rq2"):
            sections.append(("rq2", lambda: bench_rq2_cold.main(scratch, n_runs=n_runs)))
        if want("rq3"):
            sections.append(("rq3", lambda: bench_rq3_warm.main(scratch, n_runs=n_runs)))
        if want("rq4"):
            sections.append(("rq4", lambda: bench_rq4_overhead.main(scratch)))
        if want("rq5"):
            sections.append(("rq5", lambda: bench_rq5_comparison.main(scratch)))
        if want("traffic"):
            sections.append(("traffic", lambda: bench_rq5_traffic.main(scratch)))
        if want("rq6"):
            sections.append(("rq6", lambda: bench_rq6_generality.main(scratch)))
        if want("rq7"):
            sections.append(("rq7", lambda: bench_rq7_retier.main(scratch)))
        if want("rq8"):
            sections.append(("rq8", lambda: bench_rq8_online.main(scratch)))
        if want("rq9"):
            sections.append(("rq9", lambda: bench_rq9_zoo.main(scratch)))
        if want("rq10"):
            sections.append(("rq10", lambda: bench_rq10_fleet.main(scratch)))
        if want("rq11"):
            sections.append(("rq11", lambda: bench_rq11_scaleout.main(scratch)))
        if want("roofline"):
            sections.append(("roofline", roofline.main))

    failures = 0
    for name, fn in sections:
        try:
            rows = list(fn())
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0.0,exception", file=sys.stdout)
            traceback.print_exc()
            continue
        by_section[name] = rows
        for row in rows:
            print(row)
    _flush_json()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
