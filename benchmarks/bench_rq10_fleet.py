"""RQ10 (beyond-paper, DESIGN.md §14): does FLEET federation — cross-
replica trace aggregation + learned pre-warm — cut the exploration cost a
workload shift charges every replica, without changing a single token?

RQ8 shows one replica's ``RetierDaemon`` adapting to a shift it has
*seen*. A fleet of N replicas behind a load balancer is worse off: each
replica must fault its own way through the new hot set before its own
daemon learns it — N× the exploration cost for one shift. The
``FleetController`` federates the daemons: pull every replica's trace
window, merge (order-independently), replan ONCE, push the residency
overlay back — so the shift replica 0 pays for is pre-warmed on replicas
1..N-1 before they ever see it.

Workload: two prompt populations from disjoint vocab halves (A = low
embed rows, B = high rows), phases **A then B** (the shift) on every
replica, served replica-by-replica within each phase. Two passes over
the SAME per-replica request sequences, each replica one cold start,
``stats`` residency, prefetch + daemon on in BOTH passes (the only
delta is the controller):

  * **solo** — N independent servers, no fleet: replica k's phase-B
    faults are paid in full by replica k;
  * **federated** — same servers joined to one ``FleetController``
    (``sync_preload=True``), ``sync()`` after every replica×phase serve:
    when replica k serves B, the controller has already learned B from
    replica 0's window and pushed the overlay, promotions loaded
    synchronously inside ``sync()`` — between batches, off every request
    path — so follower residency is deterministic, not a prefetch race.

Every follower serve is *post-shift*: replica 0 has already served and
``sync()``ed the phase by the time replicas 1..N-1 see it, so in the
federated pass the followers' request paths should be spared the
exploration replica 0 already paid for. (The phase-B-only slice is NOT
a usable metric here: greedy decode wanders over the whole vocab, so a
solo replica's phase-A decode has already demand-faulted most phase-B
rows — what remains per phase is LRU churn noise. The exploration cost
federation removes is the followers' aggregate.)

Asserted, not just printed: per-replica greedy outputs are IDENTICAL
across passes (federation moves bytes, never tokens); aggregate
request-path fault bytes over replicas 1..N-1 — their whole post-shift
serving, both phases — are LOWER federated than solo; and a **late
joiner** — a fresh replica registered
against a controller ``restore()``d from ``snapshot()`` — is warm-
bootstrapped at register time and beats an unfederated cold join on the
same phase-B traffic, again with identical outputs. Per-replica push
failures would surface in the summary's fleet stats (must be zero).

Standalone: ``python -m benchmarks.bench_rq10_fleet [--smoke] [--json-out F]``
(wired into benchmarks/run.py as the ``rq10`` section and the CI smoke).
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile

import numpy as np

from benchmarks.bench_rq8_online import _phase_prompts, _serve_phases
from benchmarks.common import csv_row, setup_app, timed_cold_start
from repro.core import FleetController


def _solo_pass(app, phases, *, n_replicas, prompt_len, gen_steps, max_seq,
               retier_interval, budget):
    """N independent replicas, each its own daemon, no federation."""
    outs, rows = [], []
    for i in range(n_replicas):
        with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                              residency="stats", prefetch=True, device_budget_bytes=budget,
                              retier_online=True,
                              retier_interval=retier_interval) as server:
            o, r = _serve_phases(server, phases, gen_steps, max_seq)
            outs.append(o)
            rows.append(r)
    return outs, rows


def _federated_pass(app, phases, *, n_replicas, prompt_len, gen_steps, max_seq,
                    retier_interval, decay, budget):
    """Same replicas joined to one controller; sync after every serve.

    Serving is replica-major within each phase (r0 A, r1 A, ..., r0 B,
    r1 B, ...) so replica 0's window of a new phase is federated before
    replicas 1..N-1 serve it — the pre-warm the fleet exists for."""
    fleet = FleetController(decay=decay, sync_preload=True)
    servers = []
    outs = [[] for _ in range(n_replicas)]
    rows = [[] for _ in range(n_replicas)]
    try:
        for i in range(n_replicas):
            servers.append(timed_cold_start(
                app, "after2", warm_shape=(1, prompt_len),
                residency="stats", prefetch=True, device_budget_bytes=budget,
                retier_online=True, retier_interval=retier_interval,
                retier_decay=decay,
                fleet=fleet, replica_name=f"replica-{i}").__enter__())
        for prompts in phases:
            for i, server in enumerate(servers):
                o, r = _serve_phases(server, [prompts], gen_steps, max_seq)
                outs[i].extend(o)
                rows[i].extend(r)
                fleet.sync()
        daemons = [s.retier_daemon.stats.to_dict() for s in servers]
    finally:
        for s in servers:
            s.__exit__(None, None, None)
    return outs, rows, fleet, daemons


def run(
    base_dir: str,
    arch: str = "mixtral-8x22b",
    *,
    n_replicas: int = 3,
    prompt_len: int = 8,
    gen_steps: int = 8,
    n_per_phase: int = 3,
    retier_interval: int = 10_000,  # local ticks OFF: federation is the only adaptation
    retier_decay: float = 0.5,
) -> dict:
    assert n_replicas >= 2, "federation needs at least 2 replicas"
    app = setup_app(arch, base_dir)
    max_seq = prompt_len + gen_steps + 2
    # budget: everything EXCEPT one vocab half fits. The every-step units
    # (experts) are never the contested resource; "which vocab half is
    # resident" is the one real hot-set choice — exactly what the shift
    # moves and what federation can decide for a follower ahead of time.
    # (The stats preset's 50% can be smaller than the experts alone, and
    # then budget churn drowns the federation signal in expert refaults.)
    plan = app.result.plan
    embed_bytes = sum(
        u.nbytes
        for dec in plan.decisions.values() if dec.tier == 1
        for u in dec.units if u.key.startswith("embed#")
    )
    budget = plan.tier1_bytes - max(embed_bytes // 2, 1)
    a, b = _phase_prompts(app, n_per_phase=n_per_phase, prompt_len=prompt_len)
    phases = [a, b]  # the shift: every replica sees A, then B

    outs_solo, rows_solo = _solo_pass(
        app, phases, n_replicas=n_replicas, prompt_len=prompt_len,
        gen_steps=gen_steps, max_seq=max_seq, retier_interval=retier_interval,
        budget=budget)
    outs_fed, rows_fed, fleet, daemons = _federated_pass(
        app, phases, n_replicas=n_replicas, prompt_len=prompt_len,
        gen_steps=gen_steps, max_seq=max_seq, retier_interval=retier_interval,
        decay=retier_decay, budget=budget)

    # correctness gate: federation may only move bytes, never tokens —
    # every replica's outputs must match its solo baseline exactly
    for solo, fed in zip(outs_solo, outs_fed):
        for ref, got in zip(solo, fed):
            np.testing.assert_array_equal(got, ref)

    fs = fleet.stats
    assert fs.replans > 0, "fleet never replanned"
    assert fs.push_failures == 0, f"fleet push failures: {fleet.last_errors}"

    # post-shift = everything replicas 1..N-1 serve (each phase reaches a
    # follower only after replica 0 served and sync()ed it): solo, every
    # follower re-pays replica 0's exploration; federated, it was pushed
    post_solo = sum(p["fault_bytes"] for r in rows_solo[1:] for p in r)
    post_fed = sum(p["fault_bytes"] for r in rows_fed[1:] for p in r)
    assert post_fed < post_solo, (
        f"federation did not reduce post-shift fault bytes on replicas "
        f"1..N-1: {post_solo} -> {post_fed}"
    )

    # late joiner: a controller restored from snapshot() warm-bootstraps a
    # replica it has never met; compare phase-B traffic vs a cold join
    snap = fleet.snapshot()
    fleet2 = FleetController.restore(snap)
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                          residency="stats", prefetch=True, device_budget_bytes=budget,
                          retier_online=True, retier_interval=retier_interval,
                          fleet=fleet2, replica_name="late-joiner") as server:
        outs_late, rows_late = _serve_phases(server, [b], gen_steps, max_seq)
    assert fleet2.stats.bootstraps == 1, (
        f"late joiner was not warm-bootstrapped: {fleet2.last_errors}"
    )
    with timed_cold_start(app, "after2", warm_shape=(1, prompt_len),
                          residency="stats", prefetch=True, device_budget_bytes=budget,
                          retier_online=True,
                          retier_interval=retier_interval) as server:
        outs_cold, rows_cold = _serve_phases(server, [b], gen_steps, max_seq)
    for ref, got in zip(outs_cold, outs_late):
        np.testing.assert_array_equal(got, ref)
    late_fault = rows_late[0]["fault_bytes"]
    cold_fault = rows_cold[0]["fault_bytes"]
    assert late_fault < cold_fault, (
        f"snapshot warm bootstrap did not beat a cold join: "
        f"{cold_fault} -> {late_fault}"
    )

    return {
        "arch": arch,
        "n_replicas": n_replicas,
        "n_requests_per_replica": len(phases) * n_per_phase,
        "gen_steps": gen_steps,
        "fault_bytes_post_shift_solo": post_solo,
        "fault_bytes_post_shift_federated": post_fed,
        "fault_bytes_reduction": 1.0 - post_fed / max(1, post_solo),
        "phase_fault_bytes_solo": [[p["fault_bytes"] for p in r] for r in rows_solo],
        "phase_fault_bytes_federated": [[p["fault_bytes"] for p in r] for r in rows_fed],
        "late_join_fault_bytes_cold": cold_fault,
        "late_join_fault_bytes_bootstrapped": late_fault,
        "late_join_reduction": 1.0 - late_fault / max(1, cold_fault),
        "fleet": fs.to_dict(),
        "daemons": daemons,
        "restarts": 0,
        "outputs_identical": True,
    }


def main(base_dir: str, *, smoke: bool = False, archs=None) -> list[str]:
    archs = archs or (("mixtral-8x22b",) if smoke else ("mixtral-8x22b", "yi-34b"))
    kw = dict(gen_steps=6, n_per_phase=2) if smoke else {}
    rows = []
    for arch in archs:
        r = run(base_dir, arch, **kw)
        f = r["fleet"]
        rows.append(csv_row(
            f"rq10_fleet/{r['arch']}",
            0.0,
            f"post_shift_fault_bytes {r['fault_bytes_post_shift_solo']}->"
            f"{r['fault_bytes_post_shift_federated']} "
            f"(-{r['fault_bytes_reduction'] * 100:.0f}% over "
            f"{r['n_replicas'] - 1} followers)"
            f"|late_join {r['late_join_fault_bytes_cold']}->"
            f"{r['late_join_fault_bytes_bootstrapped']} "
            f"(-{r['late_join_reduction'] * 100:.0f}%)"
            f"|syncs={f['syncs']} replans={f['replans']} pushes={f['pushes']} "
            f"push_failures={f['push_failures']} bootstraps={f['bootstraps']}"
            f"|restarts=0|outputs=identical",
        ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one arch, 2 prompts x 6 steps per phase")
    ap.add_argument("--out", default="", help="artifact scratch dir (default: temp)")
    ap.add_argument("--json-out", default="",
                    help="also write the CSV rows as a JSON list here")
    args = ap.parse_args()
    scratch = args.out or tempfile.mkdtemp(prefix="faaslight_rq10_")
    print("name,us_per_call,derived")
    rows = main(scratch, smoke=args.smoke)
    for row in rows:
        print(row)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"section": "rq10", "rows": rows}, f, indent=2)
    sys.exit(0)
